//! The coordinator's lease table: which grid slices are covered,
//! leased, or waiting, and when a lease is declared dead.
//!
//! Pure bookkeeping over milliseconds-since-origin timestamps — the
//! caller supplies `now` from a [`crate::util::clock::Clock`], so the
//! expiry/reassignment logic is exhaustively testable with
//! [`crate::util::clock::MockClock`] and no real sleeps.
//!
//! Grid indices move through three states: *pending* (uncovered,
//! unleased), *leased* (granted to a worker, deadline ticking), and
//! *covered* (a validated result line is held). Expiry moves a lease's
//! uncovered indices back to pending; a late delivery from an expired
//! lease is still welcome — the server accepts the first copy of every
//! index and byte-compares any duplicate, so reassignment can only
//! add redundancy, never change bytes.

use std::collections::{BTreeMap, BTreeSet};

/// One outstanding lease over grid slice `[lo, hi)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub id: u64,
    pub lo: usize,
    pub hi: usize,
    pub worker: String,
    /// Clock time (ms) after which the lease is expired.
    pub deadline: u64,
}

/// Scheduling state for one sweep grid.
pub struct LeaseTable {
    total: usize,
    pending: BTreeSet<usize>,
    covered: BTreeSet<usize>,
    active: BTreeMap<u64, Lease>,
    next_id: u64,
    lease_timeout_ms: u64,
    min_lease: usize,
    max_lease: usize,
    /// Workers that ever held a lease (reporting only).
    workers: BTreeSet<String>,
    /// Leases that expired and were returned to the pool.
    expired: usize,
}

impl LeaseTable {
    /// A table over `total` grid indices, with everything in `covered`
    /// already done (restart resume: store prefix + cache hits).
    pub fn new(
        total: usize,
        covered: &BTreeSet<usize>,
        lease_timeout_ms: u64,
        min_lease: usize,
        max_lease: usize,
    ) -> LeaseTable {
        let covered: BTreeSet<usize> =
            covered.iter().copied().filter(|&i| i < total).collect();
        let pending = (0..total).filter(|i| !covered.contains(i)).collect();
        LeaseTable {
            total,
            pending,
            covered,
            active: BTreeMap::new(),
            next_id: 1,
            lease_timeout_ms,
            min_lease: min_lease.max(1),
            max_lease: max_lease.max(min_lease.max(1)),
            workers: BTreeSet::new(),
            expired: 0,
        }
    }

    /// Uncovered cases (pending + currently leased).
    pub fn remaining(&self) -> usize {
        self.total - self.covered.len()
    }

    /// Every index has a validated result.
    pub fn done(&self) -> bool {
        self.covered.len() == self.total
    }

    pub fn is_covered(&self, index: usize) -> bool {
        self.covered.contains(&index)
    }

    /// Outstanding lease count.
    pub fn active_leases(&self) -> usize {
        self.active.len()
    }

    /// Leases that expired over the table's lifetime.
    pub fn expired_leases(&self) -> usize {
        self.expired
    }

    /// Distinct workers that ever held a lease.
    pub fn workers_seen(&self) -> usize {
        self.workers.len()
    }

    /// Lease size target: shrink as the grid drains so the tail is
    /// spread across workers (work stealing) instead of one worker
    /// holding the last big slice while the rest idle.
    fn lease_size(&self) -> usize {
        (self.remaining() / 4).clamp(self.min_lease, self.max_lease)
    }

    /// Grant the next contiguous pending run to `worker`, or `None` if
    /// nothing is pending right now (the caller should tell the worker
    /// to wait: outstanding leases may still expire and refill the
    /// pool).
    pub fn grant(&mut self, worker: &str, now: u64) -> Option<Lease> {
        let lo = *self.pending.iter().next()?;
        let want = self.lease_size();
        let mut hi = lo + 1;
        while hi - lo < want && self.pending.contains(&hi) {
            hi += 1;
        }
        for i in lo..hi {
            self.pending.remove(&i);
        }
        let lease = Lease {
            id: self.next_id,
            lo,
            hi,
            worker: worker.to_string(),
            deadline: now + self.lease_timeout_ms,
        };
        self.next_id += 1;
        self.workers.insert(worker.to_string());
        self.active.insert(lease.id, lease.clone());
        Some(lease)
    }

    /// Renew lease `id` if `worker` still holds it. Returns false when
    /// the lease is gone (expired and possibly reassigned) — the
    /// worker should abandon the slice.
    pub fn heartbeat(&mut self, id: u64, worker: &str, now: u64) -> bool {
        match self.active.get_mut(&id) {
            Some(lease) if lease.worker == worker => {
                lease.deadline = now + self.lease_timeout_ms;
                true
            }
            _ => false,
        }
    }

    /// Expire every lease whose deadline has passed, returning its
    /// still-uncovered indices to pending. Returns the expired leases
    /// (for logging).
    pub fn expire(&mut self, now: u64) -> Vec<Lease> {
        let dead: Vec<u64> = self
            .active
            .values()
            .filter(|l| l.deadline < now)
            .map(|l| l.id)
            .collect();
        let mut out = Vec::new();
        for id in dead {
            if let Some(lease) = self.active.remove(&id) {
                for i in lease.lo..lease.hi {
                    if !self.covered.contains(&i) {
                        self.pending.insert(i);
                    }
                }
                self.expired += 1;
                out.push(lease);
            }
        }
        out
    }

    /// Mark one index covered (a validated result line is in hand).
    /// Idempotent; removes the index from pending if it was reassigned
    /// but not yet re-leased.
    pub fn cover(&mut self, index: usize) {
        if index < self.total {
            self.pending.remove(&index);
            self.covered.insert(index);
        }
    }

    /// Drop lease `id` after its results were delivered (or refused).
    /// Returns whether the lease was still active.
    pub fn release(&mut self, id: u64) -> bool {
        self.active.remove(&id).is_some()
    }

    /// Cancel lease `id` and return its uncovered indices to pending
    /// (a worker delivered garbage, or hung up mid-lease): the slice
    /// becomes immediately re-leasable instead of waiting out the
    /// deadline.
    pub fn abort(&mut self, id: u64) {
        if let Some(lease) = self.active.remove(&id) {
            for i in lease.lo..lease.hi {
                if !self.covered.contains(&i) {
                    self.pending.insert(i);
                }
            }
        }
    }

    /// Return all of `worker`'s leases to the pool (graceful `bye`).
    pub fn release_worker(&mut self, worker: &str) {
        let ids: Vec<u64> = self
            .active
            .values()
            .filter(|l| l.worker == worker)
            .map(|l| l.id)
            .collect();
        for id in ids {
            if let Some(lease) = self.active.remove(&id) {
                for i in lease.lo..lease.hi {
                    if !self.covered.contains(&i) {
                        self.pending.insert(i);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{Clock, MockClock};

    fn table(total: usize, timeout: u64) -> LeaseTable {
        LeaseTable::new(total, &BTreeSet::new(), timeout, 2, 8)
    }

    #[test]
    fn grants_cover_the_grid_in_contiguous_slices() {
        let clock = MockClock::new(0);
        let mut t = table(20, 1_000);
        let a = t.grant("w1", clock.now_millis()).unwrap();
        assert_eq!((a.lo, a.hi), (0, 5), "20 remaining / 4 = 5 cases");
        let b = t.grant("w2", clock.now_millis()).unwrap();
        assert_eq!(b.lo, a.hi, "slices are contiguous and disjoint");
        assert_eq!(t.active_leases(), 2);
        assert_eq!(t.workers_seen(), 2);
    }

    #[test]
    fn lease_sizes_shrink_as_the_grid_drains() {
        let clock = MockClock::new(0);
        let mut t = LeaseTable::new(100, &BTreeSet::new(), 1_000, 2, 64);
        let first = t.grant("w", clock.now_millis()).unwrap();
        assert_eq!(first.hi - first.lo, 25, "100/4");
        // cover everything but the last 8
        for i in first.lo..first.hi {
            t.cover(i);
        }
        t.release(first.id);
        for i in 25..92 {
            t.cover(i);
        }
        let tail = t.grant("w", clock.now_millis()).unwrap();
        assert_eq!(tail.hi - tail.lo, 2, "8 remaining / 4 = 2: tail spreads out");
    }

    #[test]
    fn expiry_returns_uncovered_indices_for_reassignment() {
        let clock = MockClock::new(0);
        let mut t = table(8, 1_000);
        let lease = t.grant("w1", clock.now_millis()).unwrap();
        assert_eq!((lease.lo, lease.hi), (0, 2));
        // half the slice was delivered before the worker died
        t.cover(0);
        // no heartbeat within the window → expired
        clock.advance(1_001);
        let dead = t.expire(clock.now_millis());
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].worker, "w1");
        assert_eq!(t.expired_leases(), 1);
        // only the uncovered index is reassigned
        let next = t.grant("w2", clock.now_millis()).unwrap();
        assert_eq!((next.lo, next.hi), (1, 3), "index 0 stays covered");
    }

    #[test]
    fn heartbeat_renews_the_deadline() {
        let clock = MockClock::new(0);
        let mut t = table(4, 1_000);
        let lease = t.grant("w", clock.now_millis()).unwrap();
        clock.advance(900);
        assert!(t.heartbeat(lease.id, "w", clock.now_millis()));
        clock.advance(900);
        assert!(t.expire(clock.now_millis()).is_empty(), "renewed at t=900");
        clock.advance(200);
        assert_eq!(t.expire(clock.now_millis()).len(), 1, "deadline was 900+1000");
    }

    #[test]
    fn heartbeat_rejects_wrong_worker_and_dead_lease() {
        let clock = MockClock::new(0);
        let mut t = table(4, 1_000);
        let lease = t.grant("w1", clock.now_millis()).unwrap();
        assert!(!t.heartbeat(lease.id, "w2", clock.now_millis()));
        clock.advance(2_000);
        t.expire(clock.now_millis());
        assert!(
            !t.heartbeat(lease.id, "w1", clock.now_millis()),
            "an expired lease cannot be revived — its slice may already be reassigned"
        );
    }

    #[test]
    fn late_duplicate_covers_only_what_is_still_open() {
        // w1's lease expires; w2 re-leases and delivers; then w1's late
        // result arrives. cover() is idempotent, so the duplicate is
        // byte-compared upstream and changes nothing here.
        let clock = MockClock::new(0);
        let mut t = table(4, 100);
        let l1 = t.grant("w1", clock.now_millis()).unwrap();
        clock.advance(200);
        t.expire(clock.now_millis());
        let l2 = t.grant("w2", clock.now_millis()).unwrap();
        assert_eq!((l2.lo, l2.hi), (l1.lo, l1.hi), "same slice reassigned");
        for i in l2.lo..l2.hi {
            t.cover(i);
        }
        t.release(l2.id);
        // late delivery from w1: release is a no-op, coverage unchanged
        assert!(!t.release(l1.id));
        for i in l1.lo..l1.hi {
            t.cover(i);
        }
        assert_eq!(t.remaining(), 4 - (l1.hi - l1.lo));
    }

    #[test]
    fn restart_resume_leases_only_uncovered_indices() {
        let clock = MockClock::new(0);
        let covered: BTreeSet<usize> = [0, 1, 2, 5].into_iter().collect();
        let mut t = LeaseTable::new(8, &covered, 1_000, 2, 64);
        assert_eq!(t.remaining(), 4);
        let a = t.grant("w", clock.now_millis()).unwrap();
        assert_eq!((a.lo, a.hi), (3, 5), "contiguous run stops at covered 5");
        let b = t.grant("w", clock.now_millis()).unwrap();
        assert_eq!((b.lo, b.hi), (6, 8));
        assert!(t.grant("w", clock.now_millis()).is_none(), "nothing pending");
        t.cover(3);
        t.cover(4);
        t.cover(6);
        t.cover(7);
        assert!(t.done());
    }

    #[test]
    fn bye_returns_a_workers_leases() {
        let clock = MockClock::new(0);
        let mut t = table(8, 1_000);
        let l = t.grant("w1", clock.now_millis()).unwrap();
        t.cover(l.lo);
        t.release_worker("w1");
        assert_eq!(t.active_leases(), 0);
        let next = t.grant("w2", clock.now_millis()).unwrap();
        assert_eq!(next.lo, l.lo + 1, "covered index not re-leased");
    }

    #[test]
    fn grant_on_empty_pool_waits_rather_than_splitting_active_leases() {
        let clock = MockClock::new(0);
        let mut t = table(2, 1_000);
        let _l = t.grant("w1", clock.now_millis()).unwrap();
        assert!(t.grant("w2", clock.now_millis()).is_none());
        assert!(!t.done(), "leased is not covered");
    }
}
