//! The coordinator⇄worker wire protocol.
//!
//! Frames are a 4-byte big-endian length prefix followed by one
//! compact JSON object (hand-rolled over [`crate::util::json`]; no new
//! dependencies). Every message is tagged by a `"type"` field. 64-bit
//! identifiers (sweep keys, seeds, lease ids) travel as 16-digit hex
//! *strings* — the codec's numbers are `f64`, which cannot hold a full
//! `u64` — matching how the store renders case keys.
//!
//! Worker → coordinator: `hello`, `request`, `heartbeat`, `result`,
//! `bye`. Coordinator → worker: `welcome`, `lease`, `wait`, `done`,
//! `ok`, `error`. The exchange is strictly request/response (one reply
//! per frame), so both sides can run plain blocking reads.
//!
//! Result lines travel as the exact rendered store lines
//! ([`crate::sweep::render_record`] is a pure function of the case and
//! outcome), so the coordinator can byte-compare duplicate deliveries
//! of a reassigned slice and write worker-supplied bytes verbatim —
//! the mechanism behind the byte-identical-store guarantee.

use std::io::{Read, Write};

use crate::util::error::{Error, Result};
use crate::util::json::{parse, Json};

/// Protocol version; bumped on any incompatible frame change.
pub const PROTO_VERSION: u64 = 1;

/// Refuse frames larger than this (a corrupt length prefix must not
/// allocate gigabytes).
const MAX_FRAME_BYTES: usize = 64 << 20;

/// One protocol message. See the module docs for the exchange shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker introduces itself.
    Hello { proto: u64, worker: String },
    /// Coordinator's session setup: the raw sweep-spec JSON text plus
    /// the resolved overrides and grid identity the worker must match.
    Welcome {
        proto: u64,
        spec: String,
        reps: usize,
        seed: u64,
        sweep_key: u64,
        cases: usize,
        heartbeat_ms: u64,
    },
    /// Worker asks for work.
    Request { worker: String },
    /// Coordinator grants grid slice `[lo, hi)` under lease `id`.
    Lease { id: u64, lo: usize, hi: usize },
    /// Nothing leasable right now (outstanding leases may yet expire);
    /// retry after `ms`.
    Wait { ms: u64 },
    /// The grid is fully covered; the worker may exit.
    Done,
    /// Worker renews lease `id`.
    Heartbeat { worker: String, lease: u64 },
    /// Worker delivers the rendered store lines for slice `[lo, hi)`
    /// computed under lease `id`.
    Result { worker: String, lease: u64, lo: usize, hi: usize, lines: Vec<String> },
    /// Generic acknowledgement. `live` is false when the acked lease is
    /// no longer held (expired and reassigned) — the worker should
    /// abandon the slice.
    Ok { live: bool },
    /// Worker is leaving; its leases can be returned to the pool.
    Bye { worker: String },
    /// Fatal coordinator-side failure (protocol violation, broken
    /// determinism contract); the worker should report it and exit.
    Error { message: String },
}

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn field<'a>(doc: &'a Json, name: &str) -> Result<&'a Json> {
    doc.get(name)
        .ok_or_else(|| Error::Parse(format!("frame missing field '{name}'")))
}

fn get_str(doc: &Json, name: &str) -> Result<String> {
    field(doc, name)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Parse(format!("frame field '{name}' is not a string")))
}

fn get_u64_hex(doc: &Json, name: &str) -> Result<u64> {
    let s = get_str(doc, name)?;
    u64::from_str_radix(&s, 16)
        .map_err(|e| Error::Parse(format!("frame field '{name}'='{s}' is not hex: {e}")))
}

fn get_usize(doc: &Json, name: &str) -> Result<usize> {
    field(doc, name)?
        .as_usize()
        .ok_or_else(|| Error::Parse(format!("frame field '{name}' is not a count")))
}

impl Message {
    /// Render to the compact JSON payload (no length prefix).
    pub fn to_json(&self) -> Json {
        match self {
            Message::Hello { proto, worker } => Json::obj(vec![
                ("proto", Json::Num(*proto as f64)),
                ("type", Json::Str("hello".into())),
                ("worker", Json::Str(worker.clone())),
            ]),
            Message::Welcome { proto, spec, reps, seed, sweep_key, cases, heartbeat_ms } => {
                Json::obj(vec![
                    ("cases", Json::Num(*cases as f64)),
                    ("heartbeat_ms", Json::Num(*heartbeat_ms as f64)),
                    ("proto", Json::Num(*proto as f64)),
                    ("reps", Json::Num(*reps as f64)),
                    ("seed", hex(*seed)),
                    ("spec", Json::Str(spec.clone())),
                    ("sweep", hex(*sweep_key)),
                    ("type", Json::Str("welcome".into())),
                ])
            }
            Message::Request { worker } => Json::obj(vec![
                ("type", Json::Str("request".into())),
                ("worker", Json::Str(worker.clone())),
            ]),
            Message::Lease { id, lo, hi } => Json::obj(vec![
                ("hi", Json::Num(*hi as f64)),
                ("id", hex(*id)),
                ("lo", Json::Num(*lo as f64)),
                ("type", Json::Str("lease".into())),
            ]),
            Message::Wait { ms } => Json::obj(vec![
                ("ms", Json::Num(*ms as f64)),
                ("type", Json::Str("wait".into())),
            ]),
            Message::Done => Json::obj(vec![("type", Json::Str("done".into()))]),
            Message::Heartbeat { worker, lease } => Json::obj(vec![
                ("lease", hex(*lease)),
                ("type", Json::Str("heartbeat".into())),
                ("worker", Json::Str(worker.clone())),
            ]),
            Message::Result { worker, lease, lo, hi, lines } => Json::obj(vec![
                ("hi", Json::Num(*hi as f64)),
                ("lease", hex(*lease)),
                ("lines", Json::Arr(lines.iter().map(|l| Json::Str(l.clone())).collect())),
                ("lo", Json::Num(*lo as f64)),
                ("type", Json::Str("result".into())),
                ("worker", Json::Str(worker.clone())),
            ]),
            Message::Ok { live } => Json::obj(vec![
                ("live", Json::Bool(*live)),
                ("type", Json::Str("ok".into())),
            ]),
            Message::Bye { worker } => Json::obj(vec![
                ("type", Json::Str("bye".into())),
                ("worker", Json::Str(worker.clone())),
            ]),
            Message::Error { message } => Json::obj(vec![
                ("message", Json::Str(message.clone())),
                ("type", Json::Str("error".into())),
            ]),
        }
    }

    /// Parse a payload back into a message.
    pub fn from_json(doc: &Json) -> Result<Message> {
        let tag = get_str(doc, "type")?;
        match tag.as_str() {
            "hello" => Ok(Message::Hello {
                proto: get_usize(doc, "proto")? as u64,
                worker: get_str(doc, "worker")?,
            }),
            "welcome" => Ok(Message::Welcome {
                proto: get_usize(doc, "proto")? as u64,
                spec: get_str(doc, "spec")?,
                reps: get_usize(doc, "reps")?,
                seed: get_u64_hex(doc, "seed")?,
                sweep_key: get_u64_hex(doc, "sweep")?,
                cases: get_usize(doc, "cases")?,
                heartbeat_ms: get_usize(doc, "heartbeat_ms")? as u64,
            }),
            "request" => Ok(Message::Request { worker: get_str(doc, "worker")? }),
            "lease" => Ok(Message::Lease {
                id: get_u64_hex(doc, "id")?,
                lo: get_usize(doc, "lo")?,
                hi: get_usize(doc, "hi")?,
            }),
            "wait" => Ok(Message::Wait { ms: get_usize(doc, "ms")? as u64 }),
            "done" => Ok(Message::Done),
            "heartbeat" => Ok(Message::Heartbeat {
                worker: get_str(doc, "worker")?,
                lease: get_u64_hex(doc, "lease")?,
            }),
            "result" => {
                let lines = field(doc, "lines")?
                    .as_arr()
                    .ok_or_else(|| Error::Parse("result 'lines' is not an array".into()))?
                    .iter()
                    .map(|l| {
                        l.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Parse("result line is not a string".into())
                        })
                    })
                    .collect::<Result<Vec<String>>>()?;
                Ok(Message::Result {
                    worker: get_str(doc, "worker")?,
                    lease: get_u64_hex(doc, "lease")?,
                    lo: get_usize(doc, "lo")?,
                    hi: get_usize(doc, "hi")?,
                    lines,
                })
            }
            "ok" => Ok(Message::Ok {
                live: field(doc, "live")?
                    .as_bool()
                    .ok_or_else(|| Error::Parse("ok 'live' is not a bool".into()))?,
            }),
            "bye" => Ok(Message::Bye { worker: get_str(doc, "worker")? }),
            "error" => Ok(Message::Error { message: get_str(doc, "message")? }),
            other => Err(Error::Parse(format!("unknown frame type '{other}'"))),
        }
    }
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, msg: &Message) -> Result<()> {
    let payload = msg.to_json().to_string_compact();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(Error::Internal(format!(
            "outgoing frame of {} bytes exceeds the {} byte cap",
            bytes.len(),
            MAX_FRAME_BYTES
        )));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame (blocking until a whole frame or an
/// I/O error — callers set socket read timeouts to bound this).
pub fn read_frame(r: &mut impl Read) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(Error::Parse(format!(
            "incoming frame claims {len} bytes, over the {MAX_FRAME_BYTES} byte cap \
             (corrupt stream?)"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| Error::Parse(format!("frame payload is not UTF-8: {e}")))?;
    Message::from_json(&parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Message::Hello { proto: PROTO_VERSION, worker: "w-1".into() });
        roundtrip(Message::Welcome {
            proto: PROTO_VERSION,
            spec: "{\"reps\": 100}".into(),
            reps: 100,
            seed: u64::MAX,
            sweep_key: 0xDEAD_BEEF_F00D_0001,
            cases: 1600,
            heartbeat_ms: 2000,
        });
        roundtrip(Message::Request { worker: "w".into() });
        roundtrip(Message::Lease { id: 7, lo: 64, hi: 128 });
        roundtrip(Message::Wait { ms: 250 });
        roundtrip(Message::Done);
        roundtrip(Message::Heartbeat { worker: "w".into(), lease: 7 });
        roundtrip(Message::Result {
            worker: "w".into(),
            lease: 7,
            lo: 0,
            hi: 2,
            lines: vec!["{\"key\":\"00\"}".into(), "{\"key\":\"01\"}".into()],
        });
        roundtrip(Message::Ok { live: true });
        roundtrip(Message::Ok { live: false });
        roundtrip(Message::Bye { worker: "w".into() });
        roundtrip(Message::Error { message: "determinism contract broken".into() });
    }

    #[test]
    fn full_u64_identifiers_survive_the_codec() {
        // Json numbers are f64; a sweep key above 2^53 would be mangled
        // as a number. The hex-string path must carry all 64 bits.
        roundtrip(Message::Lease { id: 0xFEDC_BA98_7654_3210, lo: 0, hi: 1 });
        roundtrip(Message::Heartbeat { worker: "w".into(), lease: u64::MAX });
    }

    #[test]
    fn multiple_frames_stream_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::Done).unwrap();
        write_frame(&mut buf, &Message::Wait { ms: 9 }).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame(&mut r).unwrap(), Message::Done);
        assert_eq!(read_frame(&mut r).unwrap(), Message::Wait { ms: 9 });
        assert!(read_frame(&mut r).is_err(), "stream exhausted");
    }

    #[test]
    fn oversized_and_corrupt_frames_are_refused() {
        // corrupt length prefix claiming 1 GiB
        let mut buf = Vec::new();
        buf.extend_from_slice(&(1u32 << 30).to_be_bytes());
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // truncated payload
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::Done).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // valid JSON, unknown tag
        let payload = b"{\"type\":\"warp\"}";
        let mut buf = Vec::new();
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(payload);
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("unknown frame type"), "{err}");
    }

    #[test]
    fn spec_text_with_newlines_and_quotes_survives() {
        let spec = "{\n  \"workload\": \"generate\",\n  \"note\": \"a \\\"b\\\"\"\n}";
        roundtrip(Message::Welcome {
            proto: 1,
            spec: spec.into(),
            reps: 1,
            seed: 0,
            sweep_key: 0,
            cases: 0,
            heartbeat_ms: 1,
        });
    }
}
