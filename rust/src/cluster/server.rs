//! The sweep coordinator: leases grid slices to workers over TCP and
//! assembles the canonical result store.
//!
//! One thread per connection handles the request/response protocol
//! ([`crate::cluster::protocol`]); all scheduling state lives behind
//! one mutex ([`Shared`]): the [`LeaseTable`], the per-index rendered
//! record lines, and the store/cache files. The main thread accepts
//! connections, expires dead leases on every poll tick, and lingers
//! briefly after completion so trailing workers hear `done`.
//!
//! **Durability / restart.** Every accepted result goes to the
//! estimate cache immediately (content-keyed, order-free), and the
//! grid-ordered store is extended whenever its covered prefix grows.
//! A restarted coordinator re-opens both, rebuilds coverage as
//! `store prefix ∪ cache hits`, and leases only uncovered indices —
//! graceful degradation instead of a from-scratch rerun.
//!
//! **Byte-identity.** Workers ship the exact rendered store lines;
//! the server re-renders each parsed line to validate purity, accepts
//! the first copy of every index, and byte-compares any duplicate
//! (reassigned slices, late deliveries from expired leases). Since
//! every case's RNG stream is `substream(seed, key)`, any two honest
//! computations of a case agree byte-for-byte, and the assembled store
//! equals a single-process `replica sweep` run. A duplicate that does
//! *not* match is a broken determinism contract and aborts the serve,
//! mirroring `sweep-merge`'s overlap handling.

use std::collections::BTreeSet;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::cluster::leases::LeaseTable;
use crate::cluster::protocol::{read_frame, write_frame, Message, PROTO_VERSION};
use crate::config::ClusterConfig;
use crate::sweep::grid::{ScenarioSet, SweepCase};
use crate::sweep::spec::SweepSpec;
use crate::sweep::store::{
    parse_record, render_record, CaseOutcome, EstimateCache, ResultStore,
};
use crate::util::clock::Clock;
use crate::util::error::{Error, Result};

/// Everything `cluster-serve` needs besides a clock.
pub struct ServeOptions {
    /// Raw sweep-spec JSON text (shipped verbatim to workers).
    pub spec_text: String,
    /// `--reps` override (applied before keying; shipped in `welcome`).
    pub reps_override: Option<usize>,
    /// `--seed` override (applied before keying; shipped in `welcome`).
    pub seed_override: Option<u64>,
    /// Canonical result-store path (cache derived as
    /// `<out>.cache.jsonl`, like a single-process sweep).
    pub out: PathBuf,
    /// Listen address, e.g. `127.0.0.1:7700`.
    pub listen: String,
    pub cfg: ClusterConfig,
}

/// What one serve accomplished.
#[derive(Clone, Copy, Debug)]
pub struct ServeReport {
    /// Grid size.
    pub cases: usize,
    /// Cases already covered when the serve started (restart resume).
    pub resumed: usize,
    /// Distinct workers that held a lease.
    pub workers: usize,
    /// Leases that expired and were reassigned.
    pub expired_leases: usize,
    /// Duplicate record lines received and byte-verified.
    pub duplicate_lines: usize,
}

struct Shared {
    table: LeaseTable,
    /// Validated record line per grid index (grid order).
    lines: Vec<Option<String>>,
    store: ResultStore,
    /// Store length in records: `lines[..store_len]` are on disk.
    store_len: usize,
    cache: EstimateCache,
    duplicates: usize,
    /// Broken determinism contract / unrecoverable failure.
    fatal: Option<String>,
    /// Grid fully covered and flushed; answering `done` until linger
    /// ends.
    finished: bool,
}

/// Immutable per-serve context shared with handler threads.
struct Session {
    cases: Arc<Vec<SweepCase>>,
    spec_text: String,
    reps: usize,
    seed: u64,
    sweep_key: u64,
    cfg: ClusterConfig,
}

fn lock(shared: &Mutex<Shared>) -> Result<MutexGuard<'_, Shared>> {
    shared
        .lock()
        .map_err(|_| Error::Internal("cluster state lock poisoned".into()))
}

/// Extend the on-disk store with every newly covered prefix line.
fn advance_store(s: &mut Shared) -> Result<()> {
    let mut grew = false;
    while let Some(Some(line)) = s.lines.get(s.store_len) {
        s.store.append(line)?;
        s.store_len += 1;
        grew = true;
    }
    if grew {
        s.cache.flush()?;
        s.store.flush()?;
    }
    Ok(())
}

/// Validate one delivered line against its case: it must parse, carry
/// the case's key, and re-render to the exact same bytes (rendering is
/// pure, so any honest worker passes).
fn validate_line(case: &SweepCase, line: &str) -> Result<CaseOutcome> {
    let (key, outcome) = parse_record(line)?;
    if key != case.key {
        return Err(Error::Parse(format!(
            "record key {key:016x} does not match case {} ({})",
            case.index,
            case.key_hex()
        )));
    }
    if render_record(case, &outcome) != line {
        return Err(Error::Parse(format!(
            "record for case {} does not re-render to its own bytes",
            case.index
        )));
    }
    Ok(outcome)
}

/// Process one worker frame, returning the reply. Locks `shared` only
/// for the duration of the state change — never across I/O.
fn handle(msg: Message, session: &Session, shared: &Mutex<Shared>, now: u64) -> Message {
    match try_handle(msg, session, shared, now) {
        Ok(reply) => reply,
        Err(e) => Message::Error { message: e.to_string() },
    }
}

fn try_handle(
    msg: Message,
    session: &Session,
    shared: &Mutex<Shared>,
    now: u64,
) -> Result<Message> {
    match msg {
        Message::Hello { proto, worker } => {
            if proto != PROTO_VERSION {
                return Ok(Message::Error {
                    message: format!(
                        "protocol version {proto} not supported (coordinator speaks \
                         {PROTO_VERSION})"
                    ),
                });
            }
            log::info!("cluster: worker {worker} connected");
            Ok(Message::Welcome {
                proto: PROTO_VERSION,
                spec: session.spec_text.clone(),
                reps: session.reps,
                seed: session.seed,
                sweep_key: session.sweep_key,
                cases: session.cases.len(),
                heartbeat_ms: session.cfg.heartbeat_ms,
            })
        }
        Message::Request { worker } => {
            let mut s = lock(shared)?;
            if let Some(msg) = &s.fatal {
                return Ok(Message::Error { message: msg.clone() });
            }
            for lease in s.table.expire(now) {
                log::warn!(
                    "cluster: lease {} [{}, {}) of worker {} expired; reassigning",
                    lease.id,
                    lease.lo,
                    lease.hi,
                    lease.worker
                );
            }
            if s.table.done() {
                return Ok(Message::Done);
            }
            match s.table.grant(&worker, now) {
                Some(lease) => {
                    log::info!(
                        "cluster: leased [{}, {}) to {worker} (lease {})",
                        lease.lo,
                        lease.hi,
                        lease.id
                    );
                    Ok(Message::Lease { id: lease.id, lo: lease.lo, hi: lease.hi })
                }
                None => Ok(Message::Wait { ms: session.cfg.poll_ms }),
            }
        }
        Message::Heartbeat { worker, lease } => {
            let mut s = lock(shared)?;
            let live = s.table.heartbeat(lease, &worker, now);
            Ok(Message::Ok { live })
        }
        Message::Result { worker, lease, lo, hi, lines } => {
            let mut s = lock(shared)?;
            if let Some(msg) = &s.fatal {
                return Ok(Message::Error { message: msg.clone() });
            }
            if lo >= hi || hi > session.cases.len() || lines.len() != hi - lo {
                s.table.abort(lease);
                return Ok(Message::Error {
                    message: format!(
                        "malformed result slice [{lo}, {hi}) with {} lines from {worker}",
                        lines.len()
                    ),
                });
            }
            for (offset, line) in lines.iter().enumerate() {
                let index = lo + offset;
                let case = &session.cases[index];
                let outcome = match validate_line(case, line) {
                    Ok(outcome) => outcome,
                    Err(e) => {
                        // a corrupt worker must not stall its slice:
                        // hand it straight back to the pool
                        s.table.abort(lease);
                        return Ok(Message::Error {
                            message: format!("rejected result from {worker}: {e}"),
                        });
                    }
                };
                let duplicate = s.lines[index].as_ref().map(|existing| existing == line);
                match duplicate {
                    Some(true) => {
                        s.duplicates += 1;
                    }
                    Some(false) => {
                        // two validated computations of one content key
                        // disagree: the determinism contract is broken;
                        // refuse to write another byte (like
                        // sweep-merge on mismatched overlap)
                        let msg = format!(
                            "duplicate record for case {} (key {}) differs between \
                             workers; the determinism contract is broken — aborting \
                             the serve",
                            index,
                            case.key_hex()
                        );
                        s.fatal = Some(msg.clone());
                        return Ok(Message::Error { message: msg });
                    }
                    None => {
                        if s.cache.get(case.key).is_none() {
                            s.cache.insert(case.key, outcome)?;
                        }
                        s.lines[index] = Some(line.clone());
                        s.table.cover(index);
                    }
                }
            }
            s.table.release(lease);
            advance_store(&mut s)?;
            Ok(Message::Ok { live: true })
        }
        Message::Bye { worker } => {
            let mut s = lock(shared)?;
            s.table.release_worker(&worker);
            log::info!("cluster: worker {worker} said bye");
            Ok(Message::Ok { live: false })
        }
        other => Ok(Message::Error {
            message: format!("unexpected frame from worker: {other:?}"),
        }),
    }
}

fn handler_thread(
    mut stream: TcpStream,
    session: Arc<Session>,
    shared: Arc<Mutex<Shared>>,
    clock: Arc<dyn Clock>,
) {
    // a silent peer is dropped after a lease window; live workers
    // heartbeat or re-request well within it
    let timeout = Duration::from_millis(session.cfg.lease_timeout_ms);
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match read_frame(&mut stream) {
            Ok(msg) => msg,
            Err(_) => break, // disconnect, timeout, or garbage: expiry reclaims work
        };
        let said_bye = matches!(msg, Message::Bye { .. });
        let reply = handle(msg, &session, &shared, clock.now_millis());
        if write_frame(&mut stream, &reply).is_err() {
            break;
        }
        if said_bye {
            break;
        }
    }
}

/// Run the coordinator until the grid is covered (or a fatal
/// determinism violation). Blocks; returns the final report.
pub fn serve(opts: &ServeOptions, clock: Arc<dyn Clock>) -> Result<ServeReport> {
    opts.cfg.validate()?;
    let mut spec = SweepSpec::from_json(&opts.spec_text)?;
    if let Some(reps) = opts.reps_override {
        spec.reps = reps;
    }
    if let Some(seed) = opts.seed_override {
        spec.seed = seed;
    }
    let trace = spec.load_trace()?;
    let set = ScenarioSet::from_trace(&trace, &spec)?;
    let expected = set.expected_keys();
    let sweep_key = set.sweep_key();
    let total = set.len();

    // Re-open the partially written store (restart resume) and the
    // content-keyed cache; coverage = store prefix ∪ cache hits.
    let (store, prefix) = ResultStore::open(&opts.out, &expected)?;
    let cache_path = PathBuf::from(format!("{}.cache.jsonl", opts.out.display()));
    let cache = EstimateCache::open(&cache_path)?;
    let mut lines: Vec<Option<String>> = vec![None; total];
    let mut covered: BTreeSet<usize> = BTreeSet::new();
    for (i, outcome) in prefix.iter().enumerate() {
        lines[i] = Some(render_record(&set.cases[i], outcome));
        covered.insert(i);
    }
    for i in prefix.len()..total {
        if let Some(outcome) = cache.get(set.cases[i].key) {
            lines[i] = Some(render_record(&set.cases[i], outcome));
            covered.insert(i);
        }
    }
    let resumed = covered.len();
    let table = LeaseTable::new(
        total,
        &covered,
        opts.cfg.lease_timeout_ms,
        opts.cfg.min_lease,
        opts.cfg.max_lease,
    );
    let shared = Arc::new(Mutex::new(Shared {
        table,
        lines,
        store,
        store_len: prefix.len(),
        cache,
        duplicates: 0,
        fatal: None,
        finished: false,
    }));
    // write out any cache-covered run that extends the store prefix
    advance_store(&mut lock(&shared)?)?;

    let session = Arc::new(Session {
        cases: Arc::new(set.cases),
        spec_text: opts.spec_text.clone(),
        reps: spec.reps,
        seed: spec.seed,
        sweep_key,
        cfg: opts.cfg.clone(),
    });
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| Error::Config(format!("cannot listen on {}: {e}", opts.listen)))?;
    listener.set_nonblocking(true)?;
    log::info!(
        "cluster: serving {total} cases on {} ({resumed} already covered)",
        opts.listen
    );

    let mut finished_at: Option<u64> = None;
    loop {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let session = Arc::clone(&session);
                let shared = Arc::clone(&shared);
                let clock = Arc::clone(&clock);
                std::thread::Builder::new()
                    .name("cluster-conn".into())
                    .spawn(move || handler_thread(stream, session, shared, clock))?;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
        let now = clock.now_millis();
        {
            let mut s = lock(&shared)?;
            if let Some(msg) = s.fatal.clone() {
                return Err(Error::Coordinator(msg));
            }
            for lease in s.table.expire(now) {
                log::warn!(
                    "cluster: lease {} [{}, {}) of worker {} expired; reassigning",
                    lease.id,
                    lease.lo,
                    lease.hi,
                    lease.worker
                );
            }
            if s.table.done() && !s.finished {
                advance_store(&mut s)?;
                s.finished = true;
                finished_at = Some(now);
                log::info!("cluster: grid covered; lingering for trailing workers");
            }
        }
        if let Some(t0) = finished_at {
            if now.saturating_sub(t0) >= opts.cfg.linger_ms {
                break;
            }
        }
        clock.sleep_millis(opts.cfg.poll_ms);
    }

    let s = lock(&shared)?;
    Ok(ServeReport {
        cases: total,
        resumed,
        workers: s.table.workers_seen(),
        expired_leases: s.table.expired_leases(),
        duplicate_lines: s.duplicates,
    })
}
