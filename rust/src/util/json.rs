//! Minimal JSON codec (no serde offline — see DESIGN.md §Substitutions).
//!
//! Supports the full JSON grammar we produce/consume: the AOT artifact
//! `manifest.json`, metric exports, and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// `Num` for finite values, `Null` otherwise. JSON has no NaN/∞
    /// literal, so this is the sanctioned way to emit statistics that
    /// may be undefined (e.g. an all-failed Monte-Carlo estimate) —
    /// read it back with [`Json::as_f64_or_nan`].
    pub fn num_or_null(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Inverse of [`Json::num_or_null`]: `Null` (or a missing field
    /// mapped through `unwrap_or(&Json::Null)`) reads back as NaN.
    pub fn as_f64_or_nan(&self) -> f64 {
        match self {
            Json::Num(x) => *x,
            _ => f64::NAN,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Render to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Render with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) if !x.is_finite() => {
                // JSON has no NaN/Infinity literal; a bare `NaN` token
                // would poison every consumer of the document. Callers
                // that care route through `num_or_null`; this is the
                // backstop for ones that don't.
                out.push_str("null");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Parse(format!("trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => Err(Error::Parse(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos.saturating_sub(1),
                other.map(|c| c as char)
            ))),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Parse(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::Parse("unterminated string".into())),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| {
                                Error::Parse("truncated \\u escape".into())
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| {
                                    Error::Parse("bad hex in \\u escape".into())
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => {
                        return Err(Error::Parse(format!("bad escape {:?}", other)))
                    }
                },
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| Error::Parse("invalid utf-8".into()))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Parse(format!("non-ascii number at byte {start}")))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => {
                    return Err(Error::Parse(format!("expected , or ] got {:?}", other)))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => {
                    return Err(Error::Parse(format!("expected , or }} got {:?}", other)))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_manifest_shape() {
        let src = r#"{
          "dtype": "f32", "d": 64, "m": 256,
          "entries": [
            {"name": "pg", "file": "pg.hlo.txt",
             "args": [{"shape": [64], "dtype": "f32"},
                      {"shape": [256, 64], "dtype": "f32"}],
             "outputs": 1}
          ]
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_usize().unwrap(), 64);
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), "pg");
        let arg1 = &e.get("args").unwrap().as_arr().unwrap()[1];
        let shape: Vec<usize> = arg1
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![256, 64]);
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("n", Json::Num(100.0)),
            ("xs", Json::Arr(vec![Json::Num(1.5), Json::Bool(true)])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn error_cases() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(1.5).to_string_compact(), "1.5");
    }

    #[test]
    fn non_finite_renders_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
        assert_eq!(Json::num_or_null(f64::NAN), Json::Null);
        assert_eq!(Json::num_or_null(2.5), Json::Num(2.5));
        // the document stays parseable end to end
        let doc = Json::obj(vec![("mean", Json::num_or_null(f64::NAN))]);
        let back = parse(&doc.to_string_compact()).unwrap();
        assert!(back.get("mean").unwrap().as_f64_or_nan().is_nan());
    }

    #[test]
    fn typed_accessors() {
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::Num(3.0).as_f64_or_nan(), 3.0);
        assert!(Json::Null.as_f64_or_nan().is_nan());
    }
}
