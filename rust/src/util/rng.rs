//! Deterministic pseudo-random number generation.
//!
//! A self-contained PCG-XSH-RR 64/32 generator plus a SplitMix64 seeder.
//! Monte-Carlo experiments must be reproducible across runs and
//! parallelizable across threads, so every consumer takes an explicit
//! seed and derives independent streams via [`Pcg64::split`].

/// PCG-XSH-RR with 64-bit state and 32-bit output, extended to u64
/// output by combining two draws. Small, fast, and statistically solid
/// for simulation purposes (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64: used to expand a user seed into well-mixed PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (seeded through SplitMix64 so even 0,1,2,... are fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Pcg64 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-thread / per-replication
    /// use). Deterministic: the n-th split of a given generator state is
    /// always the same.
    pub fn split(&mut self) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform f64 in (0, 1] — safe to pass to `ln()`.
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method
    /// (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller (used for synthetic regression data).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_pos();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            buckets[(u * 10.0) as usize] += 1;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        for b in buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket frac {frac}");
        }
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut parent1 = Pcg64::new(9);
        let mut parent2 = Pcg64::new(9);
        let mut c1 = parent1.split();
        let mut c2 = parent2.split();
        for _ in 0..32 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        // child differs from a fresh parent's own stream
        let mut p = Pcg64::new(9);
        let mut c = Pcg64::new(9).split();
        let same = (0..64).filter(|_| p.next_u64() == c.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    #[should_panic]
    fn below_zero_panics() {
        Pcg64::new(0).below(0);
    }
}
