//! Injectable time source for the cluster runtime.
//!
//! All wall-clock reads and sleeps in `cluster/` go through [`Clock`]
//! so that lease-expiry and heartbeat logic is testable without real
//! sleeps ([`MockClock`]) and so that detlint rule D1-TIME keeps a
//! single audited `Instant::now` call site in library code
//! ([`MonotonicClock`], this file). Timing never feeds a result path:
//! the sweep store contents are fixed by the content-keyed RNG, and
//! clocks only decide *scheduling* (when a lease expires, when a
//! worker heartbeats).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A monotonic millisecond clock plus a sleep primitive.
///
/// `now_millis` is relative to an arbitrary per-clock origin; only
/// differences are meaningful. Implementations must be monotonic
/// (never go backwards).
pub trait Clock: Send + Sync {
    /// Milliseconds since this clock's origin.
    fn now_millis(&self) -> u64;
    /// Block the calling thread for `ms` milliseconds (or simulate
    /// doing so).
    fn sleep_millis(&self, ms: u64);
}

/// The production clock: `Instant`-based monotonic time and real
/// `thread::sleep`. This is the only `Instant::now` call site allowed
/// in library code outside `src/metrics/` (see detlint D1-TIME).
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_millis(&self) -> u64 {
        self.origin.elapsed().as_millis() as u64
    }

    fn sleep_millis(&self, ms: u64) {
        std::thread::sleep(Duration::from_millis(ms));
    }
}

/// A manually-advanced clock for tests: `now_millis` reads an atomic
/// counter, `sleep_millis` advances it (so code under test that
/// "waits" makes progress instead of blocking), and tests can jump
/// time forward with [`MockClock::advance`].
pub struct MockClock {
    now: AtomicU64,
}

impl MockClock {
    pub fn new(start_millis: u64) -> MockClock {
        MockClock { now: AtomicU64::new(start_millis) }
    }

    /// Jump the clock forward by `ms` milliseconds.
    pub fn advance(&self, ms: u64) {
        self.now.fetch_add(ms, Ordering::SeqCst);
    }
}

impl Clock for MockClock {
    fn now_millis(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep_millis(&self, ms: u64) {
        self.advance(ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_is_monotonic() {
        let c = MonotonicClock::new();
        let a = c.now_millis();
        let b = c.now_millis();
        assert!(b >= a);
    }

    #[test]
    fn mock_clock_advances_on_sleep_and_advance() {
        let c = MockClock::new(100);
        assert_eq!(c.now_millis(), 100);
        c.advance(50);
        assert_eq!(c.now_millis(), 150);
        c.sleep_millis(25);
        assert_eq!(c.now_millis(), 175);
    }

    #[test]
    fn mock_clock_is_shareable() {
        use std::sync::Arc;
        let c = Arc::new(MockClock::new(0));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || c.advance(10))
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.now_millis(), 40);
    }
}
