//! A small property-testing driver (no `proptest` crate offline).
//!
//! [`forall`] runs a property closure against `cases` independent RNG
//! streams and reports the failing seed so a case can be replayed
//! deterministically:
//!
//! ```
//! use replica::util::proptest::forall;
//! forall("sum is commutative", 64, |rng| {
//!     let (a, b) = (rng.uniform(), rng.uniform());
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Run `property` against `cases` independent PCG streams derived from a
/// fixed master seed. Panics (with the case seed) on the first failure.
pub fn forall<F: FnMut(&mut Pcg64)>(name: &str, cases: u64, mut property: F) {
    for case in 0..cases {
        let seed = master_seed(name) ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case of a property by seed.
pub fn replay<F: FnMut(&mut Pcg64)>(seed: u64, mut property: F) {
    let mut rng = Pcg64::new(seed);
    property(&mut rng);
}

fn master_seed(name: &str) -> u64 {
    // FNV-1a over the property name keeps seeds stable across runs.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("counts", 16, |_rng| count += 1);
        assert_eq!(count, 16);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always fails", 4, |_rng| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_get_distinct_randomness() {
        let mut seen = std::collections::HashSet::new();
        forall("distinct", 32, |rng| {
            seen.insert(rng.next_u64());
        });
        assert_eq!(seen.len(), 32);
    }
}
