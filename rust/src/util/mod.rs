//! Shared substrates: errors, deterministic RNG, special functions,
//! JSON/CSV codecs, logging, and a small property-testing driver.
//!
//! Everything here is hand-built because the build environment is fully
//! offline (see DESIGN.md §Substitutions): no `rand`, `serde`, or
//! `proptest` — only the crates vendored with the `xla` tree.

pub mod csv;
pub mod error;
pub mod json;
pub mod logging;
pub mod math;
pub mod proptest;
pub mod rng;
