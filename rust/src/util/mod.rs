//! Shared substrates: errors, deterministic RNG, special functions,
//! JSON/CSV codecs, logging, and a small property-testing driver.
//!
//! Everything here is hand-built because the build environment is fully
//! offline (see DESIGN.md §Substitutions): no `rand`, `serde`, or
//! `proptest` — only the hermetic shims vendored under `rust/vendor/`
//! (`log`, `once_cell`, and the `xla` PJRT stub).

pub mod clock;
pub mod csv;
pub mod error;
pub mod json;
pub mod logging;
pub mod math;
pub mod proptest;
pub mod rng;
