//! Tiny CSV reader/writer for trace files and experiment exports.
//!
//! Supports quoted fields with embedded commas/newlines (RFC-4180
//! subset) — enough for Google-cluster-trace-shaped data.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use crate::util::error::{Error, Result};

/// A parsed CSV table: header row + data rows.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: Vec<&str>) -> Table {
        Table { header: header.into_iter().map(String::from).collect(), rows: Vec::new() }
    }

    /// Column index by header name.
    pub fn col(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| Error::Parse(format!("no column '{name}'")))
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        debug_assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    /// Parse a float cell.
    pub fn f64_at(&self, row: usize, col: usize) -> Result<f64> {
        self.rows[row][col]
            .parse::<f64>()
            .map_err(|e| Error::Parse(format!("bad float at ({row},{col}): {e}")))
    }

    pub fn write_to(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", encode_row(&self.header))?;
        for row in &self.rows {
            writeln!(w, "{}", encode_row(row))?;
        }
        Ok(())
    }

    pub fn read_from(path: &Path) -> Result<Table> {
        let mut text = String::new();
        File::open(path)?.read_to_string(&mut text)?;
        parse(&text)
    }
}

fn needs_quoting(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n')
}

fn encode_row(fields: &[String]) -> String {
    fields
        .iter()
        .map(|f| {
            if needs_quoting(f) {
                format!("\"{}\"", f.replace('"', "\"\""))
            } else {
                f.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Parse CSV text (first row = header).
pub fn parse(text: &str) -> Result<Table> {
    let mut records = Vec::new();
    let mut field = String::new();
    let mut record = Vec::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;

    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => record.push(std::mem::take(&mut field)),
                '\r' => {}
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err(Error::Parse("unterminated quoted field".into()));
    }
    if !field.is_empty() || !record.is_empty() {
        record.push(field);
        records.push(record);
    }
    if records.is_empty() {
        return Err(Error::Parse("empty csv".into()));
    }
    let header = records.remove(0);
    let ncols = header.len();
    for (i, r) in records.iter().enumerate() {
        if r.len() != ncols {
            return Err(Error::Parse(format!(
                "row {} has {} fields, header has {ncols}",
                i + 1,
                r.len()
            )));
        }
    }
    Ok(Table { header, rows: records })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let t = parse("a,b\n1,2\n3,4\n").unwrap();
        assert_eq!(t.header, vec!["a", "b"]);
        assert_eq!(t.rows, vec![vec!["1", "2"], vec!["3", "4"]]);
        assert_eq!(t.col("b").unwrap(), 1);
        assert!(t.col("z").is_err());
        assert_eq!(t.f64_at(1, 0).unwrap(), 3.0);
    }

    #[test]
    fn quoted_fields() {
        let t = parse("name,msg\nalice,\"hi, \"\"bob\"\"\nbye\"\n").unwrap();
        assert_eq!(t.rows[0][1], "hi, \"bob\"\nbye");
    }

    #[test]
    fn write_then_read() {
        let dir = std::env::temp_dir().join("replica_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut t = Table::new(vec!["x", "note"]);
        t.push_row(vec!["1.5".into(), "plain".into()]);
        t.push_row(vec!["2".into(), "with, comma".into()]);
        t.write_to(&path).unwrap();
        let back = Table::read_from(&path).unwrap();
        assert_eq!(back.rows, t.rows);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(parse("a,b\n1\n").is_err());
    }

    #[test]
    fn no_trailing_newline_ok() {
        let t = parse("a\n1").unwrap();
        assert_eq!(t.rows, vec![vec!["1"]]);
    }
}
