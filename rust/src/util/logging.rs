//! Minimal `log` facade backend (stderr, level from `REPLICA_LOG`).

use log::{Level, LevelFilter, Log, Metadata, Record};
use once_cell::sync::OnceCell;

struct StderrLogger {
    level: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:5}] {}: {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceCell<StderrLogger> = OnceCell::new();

/// Install the logger. Level comes from `REPLICA_LOG`
/// (error/warn/info/debug/trace, default `warn`). Idempotent.
pub fn init() {
    let level = match std::env::var("REPLICA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { level });
    // Ignore "already set" errors from repeated init (e.g. tests).
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::Trace);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
