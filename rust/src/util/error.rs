//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls instead of a `thiserror` derive:
//! the build environment is fully offline (DESIGN.md §Substitutions) and
//! proc-macro crates cannot be vendored as shims the way `log` and
//! `once_cell` are.

use std::fmt;

/// Unified error type for the `replica` crate.
#[derive(Debug)]
pub enum Error {
    /// Invalid configuration or argument values.
    Config(String),

    /// A batching/assignment policy was asked to do something infeasible
    /// (e.g. B does not divide N for a balanced assignment).
    Policy(String),

    /// Parse errors from the JSON/CSV/config codecs.
    Parse(String),

    /// I/O failures (artifact files, trace files, exports).
    Io(std::io::Error),

    /// PJRT/XLA runtime failures.
    Runtime(String),

    /// A required AOT artifact is missing from the manifest.
    MissingArtifact(String),

    /// Coordinator-level failures (worker panic, channel closed, ...).
    Coordinator(String),

    /// An internal invariant was violated. Reaching this variant is a
    /// bug in the crate, not in the caller's input; it exists so library
    /// code can propagate broken invariants instead of panicking (the
    /// detlint D2 rule).
    Internal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Policy(msg) => write!(f, "infeasible policy: {msg}"),
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::MissingArtifact(msg) => {
                write!(f, "missing artifact: {msg} (run `make artifacts`)")
            }
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::Internal(msg) => {
                write!(f, "internal invariant violated: {msg} (please file a bug)")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad N".into());
        assert_eq!(e.to_string(), "invalid configuration: bad N");
        let e = Error::MissingArtifact("grad".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn xla_error_converts_to_runtime() {
        let e: Error = xla::PjRtClient::cpu().err().unwrap().into();
        assert!(matches!(e, Error::Runtime(_)));
        assert!(e.to_string().contains("PJRT"));
    }
}
