//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for the `replica` crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid configuration or argument values.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A batching/assignment policy was asked to do something infeasible
    /// (e.g. B does not divide N for a balanced assignment).
    #[error("infeasible policy: {0}")]
    Policy(String),

    /// Parse errors from the JSON/CSV/config codecs.
    #[error("parse error: {0}")]
    Parse(String),

    /// I/O failures (artifact files, trace files, exports).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// PJRT/XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// A required AOT artifact is missing from the manifest.
    #[error("missing artifact: {0} (run `make artifacts`)")]
    MissingArtifact(String),

    /// Coordinator-level failures (worker panic, channel closed, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("bad N".into());
        assert_eq!(e.to_string(), "invalid configuration: bad N");
        let e = Error::MissingArtifact("grad".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
