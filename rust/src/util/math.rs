//! Special functions needed by the paper's closed forms.
//!
//! `std` has no `lgamma`/`digamma`, so we implement them:
//! * [`lgamma`] — Lanczos approximation (g = 7, n = 9), |err| < 1e-13
//!   over the real line (via reflection for x < 0.5).
//! * [`gamma`] — `exp(lgamma)` with sign tracking for negative x.
//! * [`digamma`] — asymptotic series with recurrence shift.
//!
//! These power eq. (22)/(24) (Pareto order-statistics moments) and the
//! digamma-based approximations in Corollary 3.

use std::f64::consts::PI;

/// Lanczos coefficients (g = 7, n = 9) — Boost/GSL standard set.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

/// Natural log of |Γ(x)|. Returns `f64::INFINITY` at non-positive
/// integers (poles).
pub fn lgamma(x: f64) -> f64 {
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let s = (PI * x).sin();
        if s == 0.0 {
            return f64::INFINITY; // pole
        }
        PI.ln() - s.abs().ln() - lgamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = LANCZOS[0];
        let t = x + LANCZOS_G + 0.5;
        for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Sign of Γ(x): +1 for x > 0; alternates between negative-integer poles.
pub fn gamma_sign(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else {
        // Γ alternates sign on (-1,0), (-2,-1), ...
        let k = (-x).floor() as i64;
        if k % 2 == 0 {
            -1.0
        } else {
            1.0
        }
    }
}

/// Γ(x) with sign handling. Overflows to ±inf for large x.
pub fn gamma(x: f64) -> f64 {
    gamma_sign(x) * lgamma(x).exp()
}

/// Digamma ψ(x) via recurrence shift to x ≥ 6 plus the asymptotic series.
pub fn digamma(mut x: f64) -> f64 {
    assert!(x > 0.0, "digamma only implemented for x > 0, got {x}");
    let mut result = 0.0;
    while x < 12.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    // ψ(x) ~ ln x − 1/(2x) − Σ B_{2n} / (2n x^{2n})
    result + x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 / 240.0)))
}

/// Γ(a)/Γ(b) computed in log space — the workhorse of eq. (22)/(24)
/// where ratios of huge Gamma values must not overflow.
pub fn gamma_ratio(a: f64, b: f64) -> f64 {
    gamma_sign(a) * gamma_sign(b) * (lgamma(a) - lgamma(b)).exp()
}

/// ln(n!) via lgamma.
pub fn lfactorial(n: u64) -> f64 {
    lgamma(n as f64 + 1.0)
}

/// Binomial coefficient C(n, k) as f64 (exact for small n, log-space for
/// large).
pub fn binomial(n: u64, k: u64) -> f64 {
    if k > n {
        return 0.0;
    }
    (lfactorial(n) - lfactorial(k) - lfactorial(n - k)).exp()
}

/// Euler–Mascheroni constant.
pub const EULER_GAMMA: f64 = 0.5772156649015329;

/// Regularized lower incomplete gamma `P(a, x) = γ(a,x)/Γ(a)` for
/// `a > 0, x ≥ 0` — series expansion for `x < a+1`, continued fraction
/// (modified Lentz) otherwise. Needed for the Gamma service-time CDF
/// (the paper's open-problem family).
pub fn gammainc_lower_regularized(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "P(a,x) needs a > 0, x ≥ 0");
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        lower_p_series(a, x)
    } else {
        (1.0 - upper_q_continued_fraction(a, x)).clamp(0.0, 1.0)
    }
}

/// Regularized upper incomplete gamma `Q(a, x) = 1 − P(a, x)`, computed
/// directly in the tail (continued fraction for `x ≥ a+1`) so deep-tail
/// survival keeps full *relative* precision instead of rounding to 0
/// where `P` saturates at 1 — the Gamma `ServiceDist::ccdf` depends on
/// this for the order-statistics integrator.
pub fn gammainc_upper_regularized(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "Q(a,x) needs a > 0, x ≥ 0");
    if x == 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        // P is not close to 1 here, so the complement loses nothing
        (1.0 - lower_p_series(a, x)).clamp(0.0, 1.0)
    } else {
        upper_q_continued_fraction(a, x)
    }
}

/// Series `γ(a,x) = x^a e^{-x} Σ x^n / (a (a+1) ... (a+n))`, valid and
/// fast-converging for `x < a + 1`.
fn lower_p_series(a: f64, x: f64) -> f64 {
    let lg = lgamma(a);
    let mut sum = 1.0 / a;
    let mut term = sum;
    let mut ap = a;
    for _ in 0..500 {
        ap += 1.0;
        term *= x / ap;
        sum += term;
        if term.abs() < sum.abs() * 1e-16 {
            break;
        }
    }
    (sum * (a * x.ln() - x - lg).exp()).clamp(0.0, 1.0)
}

/// Modified-Lentz continued fraction for `Q(a, x)`, valid for `x ≥ a+1`.
fn upper_q_continued_fraction(a: f64, x: f64) -> f64 {
    let lg = lgamma(a);
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = d * c;
        h *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    ((a * x.ln() - x - lg).exp() * h).clamp(0.0, 1.0)
}

/// Simple bisection root finder on a bracketing interval.
/// Returns the midpoint after converging to `tol` or 200 iterations.
pub fn bisect<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, tol: f64) -> Option<f64> {
    let (flo, fhi) = (f(lo), f(hi));
    if flo == 0.0 {
        return Some(lo);
    }
    if fhi == 0.0 {
        return Some(hi);
    }
    if flo.signum() == fhi.signum() {
        return None;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let fm = f(mid);
        if fm == 0.0 || (hi - lo) < tol {
            return Some(mid);
        }
        if fm.signum() == flo.signum() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn gamma_integers_are_factorials() {
        close(gamma(1.0), 1.0, 1e-12);
        close(gamma(2.0), 1.0, 1e-12);
        close(gamma(5.0), 24.0, 1e-9);
        close(gamma(10.0), 362880.0, 1e-4);
    }

    #[test]
    fn gamma_half() {
        close(gamma(0.5), PI.sqrt(), 1e-12);
        close(gamma(1.5), 0.5 * PI.sqrt(), 1e-12);
        close(gamma(-0.5), -2.0 * PI.sqrt(), 1e-10);
    }

    #[test]
    fn lgamma_large_no_overflow() {
        // ln(170!) ≈ 706.57; gamma(171) would overflow f64 if not log-space
        let l = lgamma(171.0);
        assert!((l - 706.5731).abs() < 1e-3);
        assert!(gamma_ratio(171.0, 170.0).is_finite());
        close(gamma_ratio(171.0, 170.0), 170.0, 1e-6);
    }

    #[test]
    fn gamma_reflection_negative() {
        // Γ(-1.5) = 4√π/3
        close(gamma(-1.5), 4.0 * PI.sqrt() / 3.0, 1e-10);
    }

    #[test]
    fn digamma_known_values() {
        close(digamma(1.0), -EULER_GAMMA, 1e-10);
        // ψ(2) = 1 − γ
        close(digamma(2.0), 1.0 - EULER_GAMMA, 1e-10);
        // ψ(1/2) = −γ − 2 ln 2
        close(digamma(0.5), -EULER_GAMMA - 2.0 * 2.0_f64.ln(), 1e-10);
    }

    #[test]
    fn digamma_is_derivative_of_lgamma() {
        for &x in &[0.3, 1.0, 2.5, 7.0, 42.0] {
            let h = 1e-6;
            let num = (lgamma(x + h) - lgamma(x - h)) / (2.0 * h);
            close(digamma(x), num, 1e-5);
        }
    }

    #[test]
    fn binomial_small() {
        close(binomial(5, 2), 10.0, 1e-9);
        close(binomial(10, 0), 1.0, 1e-12);
        close(binomial(10, 10), 1.0, 1e-12);
        assert_eq!(binomial(3, 5), 0.0);
    }

    #[test]
    fn gammainc_known_values() {
        // P(1, x) = 1 − e^{-x}
        for x in [0.1, 1.0, 3.0, 10.0] {
            close(gammainc_lower_regularized(1.0, x), 1.0 - (-x).exp(), 1e-12);
        }
        // P(a, 0) = 0; P(a, ∞-ish) = 1
        assert_eq!(gammainc_lower_regularized(2.5, 0.0), 0.0);
        close(gammainc_lower_regularized(2.5, 100.0), 1.0, 1e-12);
        // P(1/2, x) = erf(√x): check a tabulated point, erf(1) ≈ 0.8427007929
        close(gammainc_lower_regularized(0.5, 1.0), 0.8427007929, 1e-9);
        // P(3, 3) = 1 − e^{-3}(1 + 3 + 4.5) ≈ 0.5768099189
        close(gammainc_lower_regularized(3.0, 3.0), 0.5768099189, 1e-9);
    }

    #[test]
    fn gammainc_upper_keeps_deep_tail_precision() {
        // Q(1, x) = e^{-x}: stays a meaningful nonzero value far past the
        // point where P(1, x) saturates at 1.0
        for x in [1.0, 10.0, 50.0, 200.0] {
            let q = gammainc_upper_regularized(1.0, x);
            let want = (-x).exp();
            assert!((q - want).abs() < 1e-12 * want.max(1e-300), "x={x}: {q} vs {want}");
        }
        assert!(gammainc_upper_regularized(1.0, 50.0) > 0.0);
        assert_eq!(gammainc_upper_regularized(2.5, 0.0), 1.0);
        // complement agrees with P where both are well-conditioned
        for x in [0.5, 2.0, 5.0] {
            let p = gammainc_lower_regularized(2.5, x);
            let q = gammainc_upper_regularized(2.5, x);
            assert!((p + q - 1.0).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn gammainc_monotone_in_x() {
        let mut prev = 0.0;
        for i in 1..200 {
            let p = gammainc_lower_regularized(4.2, i as f64 * 0.1);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn bisect_finds_sqrt2() {
        let r = bisect(|x| x * x - 2.0, 0.0, 2.0, 1e-12).unwrap();
        close(r, 2.0_f64.sqrt(), 1e-10);
    }

    #[test]
    fn bisect_rejects_non_bracketing() {
        assert!(bisect(|x| x * x + 1.0, -1.0, 1.0, 1e-9).is_none());
    }
}
