//! Generalized harmonic numbers.
//!
//! `H_{(B,1)} = Σ_{k=1..B} 1/k` and `H_{(B,2)} = Σ_{k=1..B} 1/k²` appear
//! throughout §VI (Theorems 3–7): the expected maximum of B i.i.d.
//! exponentials is `H_{(B,1)}/μ` and its variance `H_{(B,2)}/μ²`.

use crate::util::math::{digamma, EULER_GAMMA};

/// First-order harmonic number `H_B = Σ_{k=1..B} 1/k` (exact summation).
pub fn h1(b: usize) -> f64 {
    (1..=b).map(|k| 1.0 / k as f64).sum()
}

/// Second-order harmonic number `Σ_{k=1..B} 1/k²` (exact summation).
pub fn h2(b: usize) -> f64 {
    (1..=b).map(|k| 1.0 / (k as f64 * k as f64)).sum()
}

/// Partial harmonic sum `Σ_{k=a..b} 1/k` (inclusive), e.g. the
/// `Σ_{k=N/2+1}^{N} 1/k` boundary in Theorem 6.
pub fn h1_range(a: usize, b: usize) -> f64 {
    (a..=b).map(|k| 1.0 / k as f64).sum()
}

/// Asymptotic `H_B ≈ ln B + γ` (used by Corollary 2's continuous
/// relaxation).
pub fn h1_approx(b: f64) -> f64 {
    b.ln() + EULER_GAMMA
}

/// `H_B` via digamma: `H_B = ψ(B+1) + γ` — exact for integer B, defined
/// for fractional arguments (used in continuous optimizers).
pub fn h1_digamma(b: f64) -> f64 {
    digamma(b + 1.0) + EULER_GAMMA
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_exact() {
        assert_eq!(h1(0), 0.0);
        assert_eq!(h1(1), 1.0);
        assert!((h1(2) - 1.5).abs() < 1e-15);
        assert!((h1(4) - 25.0 / 12.0).abs() < 1e-15);
        assert_eq!(h2(1), 1.0);
        assert!((h2(2) - 1.25).abs() < 1e-15);
        assert!((h2(3) - 49.0 / 36.0).abs() < 1e-15);
    }

    #[test]
    fn h2_converges_to_pi2_over_6() {
        let limit = std::f64::consts::PI.powi(2) / 6.0;
        assert!((h2(100_000) - limit).abs() < 1e-4);
    }

    #[test]
    fn range_sum_consistent() {
        assert!((h1_range(51, 100) - (h1(100) - h1(50))).abs() < 1e-12);
        // Theorem 6: Σ_{N/2+1..N} ≈ ln 2 for large N
        assert!((h1_range(501, 1000) - 2.0_f64.ln()).abs() < 1e-3);
    }

    #[test]
    fn digamma_form_matches_summation() {
        for b in [1usize, 2, 5, 10, 100, 1000] {
            assert!(
                (h1_digamma(b as f64) - h1(b)).abs() < 1e-9,
                "b={b}"
            );
        }
    }

    #[test]
    fn approx_close_for_large_b() {
        assert!((h1_approx(1000.0) - h1(1000)).abs() < 1e-3);
    }
}
