//! Majorization (Definitions 3–6, Lemmas 2–3).
//!
//! An assignment vector `N̄ = (N₁,…,N_B)` gives the number of workers
//! hosting each batch. Lemma 2: if `N̄₁ ⪰ N̄₂` (majorizes) then
//! `E[T(N̄₁)] ≥ E[T(N̄₂)]` for stochastically decreasing-convex service
//! times. Lemma 3: the balanced vector is majorized by every other
//! assignment — hence optimal.

/// Does `a` majorize `b`? Both must have equal length and equal sums.
pub fn majorizes(a: &[usize], b: &[usize]) -> bool {
    assert_eq!(a.len(), b.len(), "vectors must have equal length");
    let mut sa: Vec<usize> = a.to_vec();
    let mut sb: Vec<usize> = b.to_vec();
    sa.sort_unstable_by(|x, y| y.cmp(x));
    sb.sort_unstable_by(|x, y| y.cmp(x));
    if sa.iter().sum::<usize>() != sb.iter().sum::<usize>() {
        return false;
    }
    let (mut pa, mut pb) = (0usize, 0usize);
    for i in 0..sa.len() {
        pa += sa[i];
        pb += sb[i];
        if pa < pb {
            return false;
        }
    }
    true
}

/// The balanced assignment `(N/B, …, N/B)`. Panics unless B | N.
pub fn balanced(n: usize, b: usize) -> Vec<usize> {
    assert!(b >= 1 && n % b == 0, "balanced assignment needs B | N");
    vec![n / b; b]
}

/// Is the vector balanced (all entries equal)?
pub fn is_balanced(v: &[usize]) -> bool {
    v.windows(2).all(|w| w[0] == w[1])
}

/// All compositions of `n` into exactly `b` positive parts, as sorted
/// (descending) multisets — i.e. all distinct assignment shapes. Small
/// n/b only (test + experiment use).
pub fn all_assignments(n: usize, b: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(b);
    fn rec(
        remaining: usize,
        parts: usize,
        max: usize,
        cur: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if parts == 1 {
            if remaining >= 1 && remaining <= max {
                cur.push(remaining);
                out.push(cur.clone());
                cur.pop();
            }
            return;
        }
        // keep parts non-increasing to enumerate shapes once
        let hi = max.min(remaining - (parts - 1));
        for v in (1..=hi).rev() {
            cur.push(v);
            rec(remaining - v, parts - 1, v, cur, out);
            cur.pop();
        }
    }
    if b >= 1 && n >= b {
        rec(n, b, n, &mut cur, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn textbook_examples() {
        assert!(majorizes(&[3, 1], &[2, 2]));
        assert!(!majorizes(&[2, 2], &[3, 1]));
        assert!(majorizes(&[4, 0, 0], &[2, 1, 1])); // degenerate zeros allowed here
        assert!(majorizes(&[2, 2], &[2, 2])); // reflexive
        assert!(!majorizes(&[3, 1], &[2, 1])); // different sums
    }

    #[test]
    fn order_insensitive() {
        assert!(majorizes(&[1, 3], &[2, 2]));
        assert!(majorizes(&[1, 5, 2], &[3, 3, 2]));
    }

    #[test]
    fn lemma3_balanced_is_majorized_by_all() {
        // every assignment of N=12 into B=3 parts majorizes (4,4,4)
        let bal = balanced(12, 3);
        for a in all_assignments(12, 3) {
            assert!(majorizes(&a, &bal), "{a:?} should majorize {bal:?}");
        }
    }

    #[test]
    fn balanced_constructor() {
        assert_eq!(balanced(12, 4), vec![3, 3, 3, 3]);
        assert!(is_balanced(&balanced(100, 10)));
        assert!(!is_balanced(&[2, 3]));
    }

    #[test]
    #[should_panic]
    fn balanced_requires_divisibility() {
        balanced(10, 3);
    }

    #[test]
    fn all_assignments_cover_partitions() {
        // partitions of 6 into 3 positive parts: 4+1+1, 3+2+1, 2+2+2
        let a = all_assignments(6, 3);
        assert_eq!(a.len(), 3);
        assert!(a.contains(&vec![4, 1, 1]));
        assert!(a.contains(&vec![3, 2, 1]));
        assert!(a.contains(&vec![2, 2, 2]));
    }

    #[test]
    fn majorization_is_transitive_property() {
        forall("majorization transitive", 200, |rng| {
            // random partitions of n into b parts
            let b = rng.range(2, 5);
            let n = b * rng.range(2, 6);
            let parts = all_assignments(n, b);
            let x = rng.choose(&parts).clone();
            let y = rng.choose(&parts).clone();
            let z = rng.choose(&parts).clone();
            if majorizes(&x, &y) && majorizes(&y, &z) {
                assert!(majorizes(&x, &z), "{x:?} {y:?} {z:?}");
            }
        });
    }

    #[test]
    fn extreme_assignment_majorizes_everything() {
        let n = 10;
        let b = 3;
        let extreme = vec![n - (b - 1), 1, 1];
        for a in all_assignments(n, b) {
            assert!(majorizes(&extreme, &a), "{extreme:?} vs {a:?}");
        }
    }
}
