//! Closed-form analysis from the paper.
//!
//! * [`harmonic`] — generalized harmonic numbers `H_{(B,1)}, H_{(B,2)}`.
//! * [`coverage`] — Lemma 1: coupon-collector coverage probability of
//!   random batch-to-worker assignment (Fig. 3).
//! * [`closed_form`] — E\[T\] and CoV\[T\] for the balanced
//!   non-overlapping policy under the size-dependent service model:
//!   eqs. (18), (19), (21), (22), (24), (26), plus a numeric
//!   order-statistics integrator for arbitrary distributions.
//! * [`optimizer`] — the discrete optimizers and regime classification
//!   of Theorems 3–10 and Corollaries 2–4.
//! * [`majorization`] — the majorization partial order behind Lemmas
//!   2–3 (balanced assignment is majorized by every other assignment).

pub mod closed_form;
pub mod coverage;
pub mod harmonic;
pub mod majorization;
pub mod optimizer;
