//! Lemma 1 (Fig. 3): batch coverage under random assignment.
//!
//! If each of N workers draws one of B batches uniformly at random with
//! replacement (the policy of Li et al. \[72\]), the probability that
//! *every* batch is drawn at least once is
//!
//! `Pr{n ≤ N} = B!/Bᴺ · S(N, B)`
//!
//! with `S` the Stirling number of the second kind. Computed by a
//! stable occupancy recurrence (the inclusion–exclusion form
//! `Σ (−1)^{B−k} C(B,k)(k/B)^N` cancels catastrophically at N ≥ 200).


/// `Pr{all B batches covered by N random draws}` (eq. 6).
///
/// Computed by the forward occupancy recurrence rather than the
/// alternating Stirling sum: after each draw, `p[j]` is the probability
/// that exactly `j` distinct batches have been seen,
/// `p'[j] = p[j]·j/B + p[j−1]·(B−j+1)/B`. All-positive arithmetic, so
/// it is numerically stable for N, B in the hundreds where the
/// inclusion–exclusion form loses all precision to cancellation.
/// O(N·B) time.
pub fn coverage_probability(n_workers: usize, b: usize) -> f64 {
    if b == 0 {
        return 1.0; // vacuous
    }
    if n_workers < b {
        return 0.0; // pigeonhole
    }
    if b == 1 {
        return 1.0;
    }
    let bf = b as f64;
    let mut p = vec![0.0f64; b + 1];
    p[0] = 1.0;
    for _ in 0..n_workers {
        for j in (1..=b).rev() {
            p[j] = p[j] * (j as f64 / bf) + p[j - 1] * ((b - j + 1) as f64 / bf);
        }
        p[0] = 0.0;
    }
    p[b].clamp(0.0, 1.0)
}

/// Exact Stirling number of the second kind `S(n, k)` for small n via
/// the triangular recurrence (u128 — exact up to n ≈ 26 for mid k).
pub fn stirling2_exact(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    if n == 0 && k == 0 {
        return 1;
    }
    if k == 0 {
        return 0;
    }
    let mut row: Vec<u128> = vec![0; k + 1];
    row[0] = 1; // S(0,0)
    for i in 1..=n {
        // iterate j downward so we use the previous row's values
        let hi = k.min(i);
        for j in (1..=hi).rev() {
            row[j] = (j as u128) * row[j] + row[j - 1];
        }
        row[0] = 0;
    }
    row[k]
}

/// Expected number of random draws to cover all B batches (classic
/// coupon collector): `B · H_B`.
pub fn expected_draws_to_cover(b: usize) -> f64 {
    b as f64 * super::harmonic::h1(b)
}

/// Smallest N such that `coverage_probability(N, B) ≥ target`.
pub fn workers_for_coverage(b: usize, target: f64) -> usize {
    assert!((0.0..1.0).contains(&target));
    let mut n = b;
    while coverage_probability(n, b) < target {
        n += 1;
        if n > 1_000_000 {
            break;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stirling_known_values() {
        assert_eq!(stirling2_exact(0, 0), 1);
        assert_eq!(stirling2_exact(4, 2), 7);
        assert_eq!(stirling2_exact(5, 3), 25);
        assert_eq!(stirling2_exact(6, 3), 90);
        assert_eq!(stirling2_exact(10, 5), 42525);
        assert_eq!(stirling2_exact(3, 5), 0);
    }

    #[test]
    fn coverage_matches_exact_stirling() {
        // Pr = B!/B^N * S(N,B) — cross-check the log-space sum vs exact
        for (n, b) in [(4usize, 2usize), (6, 3), (10, 4), (12, 5), (20, 6)] {
            let exact = {
                let s = stirling2_exact(n, b) as f64;
                let bf: f64 = (1..=b).map(|i| i as f64).product();
                s * bf / (b as f64).powi(n as i32)
            };
            let got = coverage_probability(n, b);
            assert!((got - exact).abs() < 1e-10, "N={n} B={b}: {got} vs {exact}");
        }
    }

    #[test]
    fn boundary_cases() {
        assert_eq!(coverage_probability(3, 5), 0.0); // N < B impossible
        assert_eq!(coverage_probability(5, 1), 1.0);
        // all distinct: 5!/5^5 (float-tolerant: log-space summation)
        let f: f64 = (1..=5).map(|i| i as f64).product();
        assert!((coverage_probability(5, 5) - f / 5f64.powi(5)).abs() < 1e-12);
        assert_eq!(coverage_probability(0, 0), 1.0);
    }

    #[test]
    fn coverage_monotone_in_n_and_decreasing_in_b() {
        for b in [5usize, 10, 20] {
            let mut prev = 0.0;
            for n in b..(6 * b) {
                let p = coverage_probability(n, b);
                assert!(p >= prev - 1e-12, "not monotone at N={n} B={b}");
                prev = p;
            }
        }
        // fixed N: more batches are harder to cover
        let mut prev = 1.0;
        for b in 1..50 {
            let p = coverage_probability(100, b);
            assert!(p <= prev + 1e-12, "B={b}");
            prev = p;
        }
    }

    #[test]
    fn paper_observation_n100_b10() {
        // Fig. 3: with N=100, B=10 is covered with high probability but
        // larger B quickly fails.
        assert!(coverage_probability(100, 10) > 0.99);
        assert!(coverage_probability(100, 30) < 0.6);
        assert!(coverage_probability(100, 50) < 0.05);
    }

    #[test]
    fn coverage_matches_monte_carlo() {
        use crate::util::rng::Pcg64;
        let (n, b) = (30usize, 8usize);
        let mut rng = Pcg64::new(99);
        let trials = 200_000;
        let mut covered = 0usize;
        for _ in 0..trials {
            let mut seen = 0u64;
            for _ in 0..n {
                seen |= 1 << rng.below(b as u64);
            }
            if seen == (1 << b) - 1 {
                covered += 1;
            }
        }
        let emp = covered as f64 / trials as f64;
        let exact = coverage_probability(n, b);
        assert!((emp - exact).abs() < 0.005, "{emp} vs {exact}");
    }

    #[test]
    fn expected_draws_is_b_times_harmonic() {
        assert!((expected_draws_to_cover(1) - 1.0).abs() < 1e-12);
        assert!((expected_draws_to_cover(2) - 3.0).abs() < 1e-12);
        // B=10: 10·H_10 ≈ 29.29
        assert!((expected_draws_to_cover(10) - 29.2897).abs() < 1e-3);
    }

    #[test]
    fn workers_for_coverage_inverse() {
        let n = workers_for_coverage(10, 0.99);
        assert!(coverage_probability(n, 10) >= 0.99);
        assert!(coverage_probability(n - 1, 10) < 0.99);
    }
}
