//! Closed forms for job compute time under balanced non-overlapping
//! replication with the size-dependent batch model `T_batch = (N/B)·τ`
//! (paper §VI).
//!
//! With N workers, B batches (B | N), batch size N/B and replication
//! degree N/B:
//!
//! * τ ~ Exp(μ):   `E[T] = H_B/μ` (eq. 26), `CoV = √H₂/H₁` (eq. 18)
//! * τ ~ SExp(Δ,μ): `E[T] = NΔ/B + H_B/μ` (eq. 19),
//!   `CoV = √H₂ / (NΔμ/B + H₁)` (eq. 21)
//! * τ ~ Pareto(σ,α): `E[T] = (Nσ/B)·Γ(B+1)Γ(1−B/(Nα))/Γ(B+1−B/(Nα))`
//!   (eq. 22), CoV per eq. (24)
//!
//! plus a numeric integrator for arbitrary distributions/assignments
//! used to cross-validate the formulas and to handle empirical τ.

use crate::analysis::harmonic::{h1, h2};
use crate::dist::ServiceDist;
use crate::util::math::lgamma;

/// E\[T\] for τ ~ Exp(μ) (eq. 26). Independent of N: replication exactly
/// cancels the size-dependent slowdown.
pub fn exp_mean(b: usize, mu: f64) -> f64 {
    h1(b) / mu
}

/// Var\[T\] for τ ~ Exp(μ): maximum of B i.i.d. Exp(μ).
pub fn exp_var(b: usize, mu: f64) -> f64 {
    h2(b) / (mu * mu)
}

/// CoV\[T\] for τ ~ Exp (eq. 18) — scale-free.
pub fn exp_cov(b: usize) -> f64 {
    h2(b).sqrt() / h1(b)
}

/// E\[T\] for τ ~ SExp(Δ, μ) (eq. 19 / 33).
pub fn sexp_mean(n: usize, b: usize, delta: f64, mu: f64) -> f64 {
    n as f64 * delta / b as f64 + h1(b) / mu
}

/// Var\[T\] for τ ~ SExp: the shift is deterministic, so the variance is
/// that of the max of B exponentials.
pub fn sexp_var(b: usize, mu: f64) -> f64 {
    h2(b) / (mu * mu)
}

/// CoV\[T\] for τ ~ SExp (eq. 21).
pub fn sexp_cov(n: usize, b: usize, delta: f64, mu: f64) -> f64 {
    h2(b).sqrt() / (n as f64 * delta * mu / b as f64 + h1(b))
}

/// E\[T\] for τ ~ Pareto(σ, α) (eq. 22). Requires `B/(Nα) < 1` for a
/// finite mean; returns ∞ otherwise.
pub fn pareto_mean(n: usize, b: usize, sigma: f64, alpha: f64) -> f64 {
    let (n, bf) = (n as f64, b as f64);
    let inv_a = bf / (n * alpha); // 1/α' of the batch-level Pareto
    if inv_a >= 1.0 {
        return f64::INFINITY;
    }
    (n * sigma / bf)
        * (lgamma(bf + 1.0) + lgamma(1.0 - inv_a) - lgamma(bf + 1.0 - inv_a)).exp()
}

/// Var\[T\] for τ ~ Pareto (eq. 76). Requires `2B/(Nα) < 1`.
pub fn pareto_var(n: usize, b: usize, sigma: f64, alpha: f64) -> f64 {
    let (nf, bf) = (n as f64, b as f64);
    let inv_a = bf / (nf * alpha);
    if 2.0 * inv_a >= 1.0 {
        return f64::INFINITY;
    }
    let scale = nf * sigma / bf;
    let e2 = scale
        * scale
        * (lgamma(bf + 1.0) + lgamma(1.0 - 2.0 * inv_a) - lgamma(bf + 1.0 - 2.0 * inv_a))
            .exp();
    let m = pareto_mean(n, b, sigma, alpha);
    e2 - m * m
}

/// CoV\[T\] for τ ~ Pareto — independent of σ.
///
/// Note: the paper's printed eq. (24) is inconsistent with its own
/// variance derivation (eqs. 75–76): at B = 1 it yields
/// `CoV² = x/(1−2x)` (x = B/(Nα)) instead of the correct
/// `x²/(1−2x)` for a Pareto maximum. We therefore derive CoV from
/// eqs. (61) and (76) directly, in log-space:
///
/// `CoV² = Γ(1−2x)·Γ(B+1−x)² / (Γ(B+1)·Γ(B+1−2x)·Γ(1−x)²) − 1`.
///
/// This corrected form reproduces Theorem 10 (monotone increasing in B,
/// minimum at full diversity) and matches Monte-Carlo simulation; the
/// typo'd form does not match simulation.
pub fn pareto_cov(n: usize, b: usize, alpha: f64) -> f64 {
    let (nf, bf) = (n as f64, b as f64);
    let x = bf / (nf * alpha);
    if 2.0 * x >= 1.0 {
        return f64::INFINITY;
    }
    let log_ratio = lgamma(1.0 - 2.0 * x) + 2.0 * lgamma(bf + 1.0 - x)
        - lgamma(bf + 1.0)
        - lgamma(bf + 1.0 - 2.0 * x)
        - 2.0 * lgamma(1.0 - x);
    (log_ratio.exp() - 1.0).max(0.0).sqrt()
}

/// Dispatch E\[T\](B) for any analytic τ family under the balanced
/// non-overlapping policy; falls back to numeric integration for
/// non-closed families.
pub fn mean_t(n: usize, b: usize, tau: &ServiceDist) -> f64 {
    match tau {
        ServiceDist::Exp { mu } => exp_mean(b, *mu),
        ServiceDist::ShiftedExp { delta, mu } => sexp_mean(n, b, *delta, *mu),
        ServiceDist::Pareto { sigma, alpha } => pareto_mean(n, b, *sigma, *alpha),
        other => numeric_mean_t(n, b, other),
    }
}

/// Dispatch CoV\[T\](B), mirroring [`mean_t`].
pub fn cov_t(n: usize, b: usize, tau: &ServiceDist) -> f64 {
    match tau {
        ServiceDist::Exp { .. } => exp_cov(b),
        ServiceDist::ShiftedExp { delta, mu } => sexp_cov(n, b, *delta, *mu),
        ServiceDist::Pareto { alpha, .. } => pareto_cov(n, b, *alpha),
        other => {
            let (m, v) = numeric_mean_var_t(n, b, other);
            v.sqrt() / m
        }
    }
}

/// Expected total cost in worker-seconds for the balanced policy under
/// **up-front** replication with kill-at-batch-completion: every one of
/// the batch's `r = N/B` replicas runs until the batch's first
/// finisher, so
///
/// `cost = B · r · E[min_r((N/B)·τ)] = N · E[min_r(k·τ)]`, `k = N/B`.
///
/// Closed per family (`x = B/(Nα)` for Pareto):
///
/// * Exp(μ): `k·τ ~ Exp(μ/k)`, min of r ~ Exp(rμ/k) = Exp(μ) → `N/μ` —
///   independent of B, replication exactly cancels the size-dependent
///   slowdown in cost just as it does in E\[T\] at B = 1.
/// * SExp(Δ,μ): shift survives the min → `N·(kΔ + 1/μ)`.
/// * Pareto(σ,α): min of r ~ Pareto(kσ, rα) → `N·kσ/(1 − x)` when
///   `x < 1`, ∞ otherwise (same divergence threshold as the mean).
///
/// Falls back to numeric integration of `S_batch(t)^r` for non-closed
/// families. Timed policies have no closed cost; they go through MC.
pub fn cost_t(n: usize, b: usize, tau: &ServiceDist) -> f64 {
    let (nf, bf) = (n as f64, b as f64);
    let k = nf / bf; // batch size = replication degree
    match tau {
        ServiceDist::Exp { mu } => nf / mu,
        ServiceDist::ShiftedExp { delta, mu } => nf * (k * delta + 1.0 / mu),
        ServiceDist::Pareto { sigma, alpha } => {
            let x = bf / (nf * alpha); // 1/(rα) of the batch-level min
            if x >= 1.0 {
                f64::INFINITY
            } else {
                nf * k * sigma / (1.0 - x)
            }
        }
        other => {
            let r = n / b;
            let batch = ServiceDist::scaled(k, other.clone());
            let s_min = |t: f64| batch.ccdf(t).powi(r as i32);
            nf * mean_var_from_survival(s_min, &batch, r, 1).0
        }
    }
}

/// Numeric E\[T\] for the balanced policy with arbitrary τ: batch service
/// is `(N/B)·τ`, replicated on N/B workers, T = max over B batches.
pub fn numeric_mean_t(n: usize, b: usize, tau: &ServiceDist) -> f64 {
    numeric_mean_var_t(n, b, tau).0
}

/// Numeric (E\[T\], Var\[T\]) by integrating the survival function of
/// `T = max_i min_{j≤N/B} (N/B)·τ_ij`.
pub fn numeric_mean_var_t(n: usize, b: usize, tau: &ServiceDist) -> (f64, f64) {
    assert!(b >= 1 && n >= b && n % b == 0, "balanced policy needs B | N");
    let r = n / b; // replicas per batch
    let batch = ServiceDist::scaled((n / b) as f64, tau.clone());
    // Survival of one batch's compute time (min over r replicas):
    //   S_batch(t) = S(t)^r ; CDF of the job: (1 − S^r)^B.
    let s_job = |t: f64| -> f64 {
        let s = batch.ccdf(t);
        1.0 - (1.0 - s.powi(r as i32)).powi(b as i32)
    };
    mean_var_from_survival(s_job, &batch, r, b)
}

/// Numeric (E\[T\], Var\[T\]) for an *arbitrary assignment vector*
/// `n_i` (workers per batch): T = max_i min_{j≤n_i} batch_i — used by the
/// majorization experiments (Lemma 2).
pub fn numeric_mean_var_assignment(
    assignment: &[usize],
    batch: &ServiceDist,
) -> (f64, f64) {
    assert!(!assignment.is_empty());
    assert!(assignment.iter().all(|&x| x >= 1));
    let s_job = |t: f64| -> f64 {
        let s = batch.ccdf(t);
        let mut prod = 1.0;
        for &ni in assignment {
            prod *= 1.0 - s.powi(ni as i32);
        }
        1.0 - prod
    };
    let rmin = assignment.iter().copied().max().unwrap_or(1);
    mean_var_from_survival(s_job, batch, rmin, assignment.len())
}

/// Integrate E[T] = ∫ S(t) dt and E[T²] = ∫ 2 t S(t) dt by trapezoid on
/// an adaptive grid reaching the far tail of the *max* distribution.
fn mean_var_from_survival<F: Fn(f64) -> f64>(
    s_job: F,
    batch: &ServiceDist,
    _r: usize,
    b: usize,
) -> (f64, f64) {
    // Upper limit: the max of B batch-minima is below the batch's own
    // extreme quantile with overwhelming probability. Push far into the
    // tail (heavy tails need room), then extend until S < 1e-9.
    let mut hi = batch.quantile(1.0 - 1e-9 / (b as f64).max(1.0));
    if !hi.is_finite() || hi <= 0.0 {
        hi = 1e6;
    }
    while s_job(hi) > 1e-9 && hi < 1e15 {
        hi *= 2.0;
    }
    let steps = 50_000usize;
    let dt = hi / steps as f64;
    let mut e1 = 0.0;
    let mut e2 = 0.0;
    let mut prev_s = s_job(0.0);
    for i in 1..=steps {
        let t = i as f64 * dt;
        let s = s_job(t);
        // trapezoid on S(t) and on 2 t S(t)
        e1 += 0.5 * (prev_s + s) * dt;
        let tm = t - 0.5 * dt;
        e2 += 2.0 * tm * 0.5 * (prev_s + s) * dt;
        prev_s = s;
    }
    (e1, e2 - e1 * e1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;

    fn close_rel(a: f64, b: f64, tol: f64) {
        assert!(
            (a - b).abs() / b.abs().max(1e-12) < tol,
            "{a} vs {b} (rel {})",
            (a - b).abs() / b.abs().max(1e-12)
        );
    }

    #[test]
    fn exp_b1_is_plain_mean() {
        // B=1: max of one Exp(μ) = 1/μ
        assert!((exp_mean(1, 2.0) - 0.5).abs() < 1e-12);
        assert!((exp_cov(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exp_mean_is_monotone_increasing_in_b() {
        // Theorem 3: full diversity (B=1) minimizes E[T]
        let mut prev = 0.0;
        for b in 1..=100 {
            let m = exp_mean(b, 1.0);
            assert!(m > prev);
            prev = m;
        }
    }

    #[test]
    fn exp_cov_is_monotone_decreasing_in_b() {
        // Theorem 4: full parallelism minimizes CoV
        let mut prev = f64::INFINITY;
        for b in 1..=1000 {
            let c = exp_cov(b);
            assert!(c < prev, "B={b}");
            prev = c;
        }
    }

    #[test]
    fn sexp_reduces_to_exp_when_delta_zero() {
        for b in [1usize, 2, 10, 50] {
            close_rel(sexp_mean(100, b, 0.0, 2.0), exp_mean(b, 2.0), 1e-12);
            close_rel(sexp_cov(100, b, 0.0, 2.0), exp_cov(b), 1e-12);
        }
    }

    #[test]
    fn sexp_b_extremes_match_theorem6_proof() {
        // Proof of Thm 6: B=1 → NΔ + 1/μ ; B=2 → NΔ/2 + 3/(2μ)
        let (n, d, mu) = (100, 0.05, 1.0);
        close_rel(sexp_mean(n, 1, d, mu), n as f64 * d + 1.0 / mu, 1e-12);
        close_rel(sexp_mean(n, 2, d, mu), n as f64 * d / 2.0 + 1.5 / mu, 1e-12);
    }

    #[test]
    fn pareto_b1_equals_scaled_pareto_mean() {
        // B=1: T = min over N workers of N·σ Pareto → mean = ... eq(22)
        // with B=1 reduces to Nσ·Γ(2)Γ(1−1/(Nα))/Γ(2−1/(Nα)) = Nσ/(1−1/(Nα))·(1/1)
        let (n, sigma, alpha) = (10usize, 1.0, 2.0);
        let inv = 1.0 / (n as f64 * alpha);
        let want = n as f64 * sigma / (1.0 - inv);
        close_rel(pareto_mean(n, 1, sigma, alpha), want, 1e-10);
    }

    #[test]
    fn pareto_mean_infinite_when_tail_too_heavy() {
        // B/(Nα) ≥ 1 → infinite mean
        assert!(pareto_mean(4, 4, 1.0, 0.9).is_infinite());
        assert!(pareto_mean(100, 100, 1.0, 1.0).is_infinite());
    }

    #[test]
    fn pareto_cov_independent_of_sigma() {
        let c1 = pareto_cov(100, 10, 2.5);
        // same α, any σ: identical (eq. 24 has no σ)
        let m1 = pareto_mean(100, 10, 1.0, 2.5);
        let v1 = pareto_var(100, 10, 1.0, 2.5);
        close_rel(v1.sqrt() / m1, c1, 1e-9);
        let m2 = pareto_mean(100, 10, 7.0, 2.5);
        let v2 = pareto_var(100, 10, 7.0, 2.5);
        close_rel(v2.sqrt() / m2, c1, 1e-9);
    }

    #[test]
    fn pareto_cov_increasing_in_b_theorem10() {
        // Theorem 10: CoV minimized at full diversity (B=1), increasing in B
        let n = 100;
        let alpha = 3.0;
        let mut prev = 0.0;
        for b in [1usize, 2, 4, 5, 10, 20, 25, 50, 100] {
            let c = pareto_cov(n, b, alpha);
            assert!(c > prev, "B={b}: {c} <= {prev}");
            prev = c;
        }
    }

    #[test]
    fn numeric_integrator_matches_exp_closed_form() {
        let tau = ServiceDist::exp(1.0);
        for (n, b) in [(10usize, 1usize), (10, 2), (10, 5), (10, 10)] {
            let (m, v) = numeric_mean_var_t(n, b, &tau);
            close_rel(m, exp_mean(b, 1.0), 2e-3);
            close_rel(v, exp_var(b, 1.0), 2e-2);
        }
    }

    #[test]
    fn numeric_integrator_matches_sexp_closed_form() {
        let tau = ServiceDist::shifted_exp(0.05, 1.0);
        for (n, b) in [(20usize, 2usize), (20, 4), (20, 10)] {
            let (m, _v) = numeric_mean_var_t(n, b, &tau);
            close_rel(m, sexp_mean(n, b, 0.05, 1.0), 2e-3);
        }
    }

    #[test]
    fn numeric_integrator_matches_pareto_closed_form() {
        let tau = ServiceDist::pareto(1.0, 3.0);
        for (n, b) in [(20usize, 2usize), (20, 4), (20, 10)] {
            let (m, _v) = numeric_mean_var_t(n, b, &tau);
            close_rel(m, pareto_mean(n, b, 1.0, 3.0), 5e-3);
        }
    }

    #[test]
    fn assignment_integrator_balanced_matches_policy_form() {
        let tau = ServiceDist::exp(1.0);
        let batch = ServiceDist::scaled(5.0, tau.clone()); // N/B = 5
        // N=10, B=2, balanced: (5,5)
        let (m_bal, _) = numeric_mean_var_assignment(&[5, 5], &batch);
        let (m_pol, _) = numeric_mean_var_t(10, 2, &tau);
        close_rel(m_bal, m_pol, 1e-6);
    }

    #[test]
    fn lemma2_balanced_beats_unbalanced_numerically() {
        // Lemma 2/3: (5,5) ⪯ (6,4) ⪯ (9,1) ⇒ E[T] ordered the same way
        let batch = ServiceDist::scaled(5.0, ServiceDist::exp(1.0));
        let (m55, _) = numeric_mean_var_assignment(&[5, 5], &batch);
        let (m64, _) = numeric_mean_var_assignment(&[6, 4], &batch);
        let (m91, _) = numeric_mean_var_assignment(&[9, 1], &batch);
        assert!(m55 < m64, "{m55} !< {m64}");
        assert!(m64 < m91, "{m64} !< {m91}");
    }

    #[test]
    fn cost_closed_forms_match_numeric_min_integral() {
        // exercise the numeric fallback through families with no closed
        // cost arm that alias a closed one: Weibull(1, 1/μ) ≡ Exp(μ)
        // and Bimodal(p_slow = 0) ≡ SExp(fast)
        for b in [1usize, 2, 4, 10, 20] {
            close_rel(
                cost_t(20, b, &ServiceDist::weibull(1.0, 1.0)),
                cost_t(20, b, &ServiceDist::exp(1.0)),
                5e-3,
            );
            close_rel(
                cost_t(20, b, &ServiceDist::bimodal(0.0, (0.05, 1.0), (1.0, 0.5))),
                cost_t(20, b, &ServiceDist::shifted_exp(0.05, 1.0)),
                5e-3,
            );
        }
    }

    #[test]
    fn cost_closed_forms_are_sane() {
        // Exp: cost = N/μ regardless of B
        for b in [1usize, 2, 5, 10] {
            close_rel(cost_t(10, b, &ServiceDist::exp(2.0)), 5.0, 1e-12);
        }
        // SExp: N·(kΔ + 1/μ), decreasing in B through the shift term
        close_rel(cost_t(10, 1, &ServiceDist::shifted_exp(0.1, 1.0)), 20.0, 1e-12);
        close_rel(cost_t(10, 10, &ServiceDist::shifted_exp(0.1, 1.0)), 11.0, 1e-12);
        // Pareto: N·kσ/(1 − B/(Nα)), ∞ past the divergence threshold
        let c = cost_t(10, 10, &ServiceDist::pareto(1.0, 2.0));
        close_rel(c, 10.0 / (1.0 - 0.5), 1e-12);
        assert!(cost_t(4, 4, &ServiceDist::pareto(1.0, 0.9)).is_infinite());
    }

    #[test]
    fn dispatchers_agree_with_family_functions() {
        let n = 100;
        let b = 10;
        close_rel(mean_t(n, b, &ServiceDist::exp(2.0)), exp_mean(b, 2.0), 1e-12);
        close_rel(
            mean_t(n, b, &ServiceDist::shifted_exp(0.05, 1.0)),
            sexp_mean(n, b, 0.05, 1.0),
            1e-12,
        );
        close_rel(
            cov_t(n, b, &ServiceDist::pareto(1.0, 3.0)),
            pareto_cov(n, b, 3.0),
            1e-12,
        );
    }
}
