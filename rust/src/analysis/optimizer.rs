//! Discrete redundancy-level optimizers and regime classification
//! (paper §VI: Theorems 3–10, Corollaries 2–4).
//!
//! The feasible set `F_B` is the set of divisors of N (balanced
//! non-overlapping batches need B | N). `B = 1` is *full diversity*
//! (every worker hosts the whole job), `B = N` is *full parallelism*
//! (no redundancy).

use crate::analysis::closed_form;
use crate::analysis::harmonic::{h1, h1_range, h2};
use crate::dist::ServiceDist;
use crate::util::math::bisect;

/// Where the optimum sits in the diversity–parallelism spectrum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Optimal at `B = 1` (maximum redundancy).
    FullDiversity,
    /// Optimal strictly inside the spectrum.
    Middle,
    /// Optimal at `B = N` (no redundancy).
    FullParallelism,
    /// Optimal at one of the two ends (Theorem 7's middle band).
    EitherEnd,
}

/// All feasible batch counts: divisors of N, ascending.
pub fn feasible_b(n: usize) -> Vec<usize> {
    assert!(n >= 1);
    let mut divs: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
    divs.sort_unstable();
    divs
}

/// argmin over F_B of E\[T\](B) via the closed forms (exact for
/// Exp/SExp/Pareto; numeric integration otherwise). Returns
/// `(B*, E[T](B*))`.
pub fn optimal_b_mean(n: usize, tau: &ServiceDist) -> (usize, f64) {
    argmin_over_feasible(n, |b| closed_form::mean_t(n, b, tau))
}

/// argmin over F_B of CoV\[T\](B). Returns `(B*, CoV(B*))`.
pub fn optimal_b_cov(n: usize, tau: &ServiceDist) -> (usize, f64) {
    argmin_over_feasible(n, |b| closed_form::cov_t(n, b, tau))
}

/// argmin over F_B of a weighted trade-off
/// `w · E[T]/E[T](B_mean*) + (1−w) · CoV/CoV(B_cov*)` — the "system
/// administrator's middle point" the paper's §VI-A discussion motivates.
pub fn optimal_b_tradeoff(n: usize, tau: &ServiceDist, w: f64) -> (usize, f64) {
    assert!((0.0..=1.0).contains(&w));
    let (_, best_mean) = optimal_b_mean(n, tau);
    let (_, best_cov) = optimal_b_cov(n, tau);
    argmin_over_feasible(n, |b| {
        let m = closed_form::mean_t(n, b, tau) / best_mean.max(1e-300);
        let c = closed_form::cov_t(n, b, tau) / best_cov.max(1e-300);
        w * m + (1.0 - w) * c
    })
}

fn argmin_over_feasible<F: Fn(usize) -> f64>(n: usize, f: F) -> (usize, f64) {
    let mut best = (1usize, f64::INFINITY);
    for b in feasible_b(n) {
        let v = f(b);
        if v < best.1 {
            best = (b, v);
        }
    }
    best
}

// --------------------------------------------------------------- SExp

/// Theorem 6: regime of the E\[T\]-optimal point for τ ~ SExp(Δ, μ).
pub fn sexp_mean_regime(n: usize, delta: f64, mu: f64) -> Regime {
    let dm = delta * mu;
    let lo = 1.0 / n as f64;
    let hi = h1_range(n / 2 + 1, n); // Σ_{N/2+1..N} 1/k
    if dm < lo {
        Regime::FullDiversity
    } else if dm <= hi {
        Regime::Middle
    } else {
        Regime::FullParallelism
    }
}

/// Corollary 2: inside the middle band, `B* ≈ argmin_B |B − NΔμ|` over
/// F_B.
pub fn sexp_mean_optimal_b_cor2(n: usize, delta: f64, mu: f64) -> usize {
    let target = n as f64 * delta * mu;
    feasible_b(n)
        .into_iter()
        .min_by(|&a, &b| (a as f64 - target).abs().total_cmp(&(b as f64 - target).abs()))
        .unwrap_or(n)
}

/// Theorem 7: regime of the CoV-optimal point for τ ~ SExp.
pub fn sexp_cov_regime(n: usize, delta: f64, mu: f64) -> Regime {
    assert!(n > 4, "Theorem 7 assumes N > 4");
    let dm = delta * mu;
    let nf = n as f64;
    let lo = 3.0 / ((5.0f64.sqrt() - 1.0) * nf);
    let hn1 = h1(n);
    let hn2 = h2(n);
    let hh1 = h1(n / 2);
    let hh2 = h2(n / 2);
    let hi = (hn1 * hh2.sqrt() - hh1 * hn2.sqrt()) / (2.0 * hn2.sqrt() - hh2.sqrt());
    if dm < lo {
        Regime::FullParallelism
    } else if dm <= hi {
        Regime::EitherEnd
    } else {
        Regime::FullDiversity
    }
}

/// Corollary 3: resolve Theorem 7's EitherEnd band for N > 11 by
/// comparing the CoV at B = 1 vs B = N.
pub fn sexp_cov_optimal_end(n: usize, delta: f64, mu: f64) -> Regime {
    let dm = delta * mu;
    let threshold = h1(n) / (n as f64 * (h2(n).sqrt()) - 1.0);
    if dm < threshold {
        Regime::FullParallelism
    } else {
        Regime::FullDiversity
    }
}

// --------------------------------------------------------------- Pareto

/// Theorem 9 / eq. (23): the critical tail index α* for τ ~ Pareto.
/// For α < α* the E\[T\]-optimum is interior; for α ≥ α* it is at full
/// parallelism.
pub fn pareto_alpha_star(n: usize) -> f64 {
    let nf = n as f64;
    let f = |alpha: f64| {
        (4.0 * alpha * alpha + (alpha - 1.0).powi(2)) / (2.0 * alpha * (alpha - 1.0))
            - std::f64::consts::PI.sqrt()
                * nf.powf(-1.0 / (2.0 * alpha))
                * 2.0f64.powf(1.0 + 1.0 / (2.0 * alpha))
            - 0.58
    };
    // f is negative just above 1 (LHS→∞? actually LHS→∞ as α→1⁺ ... the
    // bracket below is found by scanning.
    let mut lo = 1.01;
    let mut flo = f(lo);
    let mut hi = lo;
    for _ in 0..200 {
        hi += 0.25;
        let fhi = f(hi);
        if flo.signum() != fhi.signum() {
            return bisect(f, lo, hi, 1e-10).unwrap_or(hi);
        }
        lo = hi;
        flo = fhi;
    }
    f64::INFINITY
}

/// Theorem 9: regime of the E\[T\]-optimal point for τ ~ Pareto(σ, α),
/// α > 1.
pub fn pareto_mean_regime(n: usize, alpha: f64) -> Regime {
    assert!(alpha > 1.0, "Theorem 9 assumes α > 1");
    if alpha >= pareto_alpha_star(n) {
        Regime::FullParallelism
    } else {
        Regime::Middle
    }
}

/// Theorem 10: the CoV-optimal point for τ ~ Pareto is always full
/// diversity.
pub fn pareto_cov_regime() -> Regime {
    Regime::FullDiversity
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_b_divisors() {
        assert_eq!(feasible_b(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(feasible_b(100), vec![1, 2, 4, 5, 10, 20, 25, 50, 100]);
        assert_eq!(feasible_b(1), vec![1]);
        assert_eq!(feasible_b(7), vec![1, 7]);
    }

    #[test]
    fn theorem3_exp_full_diversity() {
        // Exp: E[T] minimized at B=1 regardless of μ
        for mu in [0.1, 1.0, 10.0] {
            let (b, _) = optimal_b_mean(100, &ServiceDist::exp(mu));
            assert_eq!(b, 1, "mu={mu}");
        }
    }

    #[test]
    fn theorem4_exp_cov_full_parallelism() {
        let (b, _) = optimal_b_cov(100, &ServiceDist::exp(1.0));
        assert_eq!(b, 100);
    }

    #[test]
    fn theorem6_regimes_for_paper_parameters() {
        // N=100, Δ=0.05 → 1/N = 0.01, Σ_{51..100}1/k ≈ 0.688
        // μ < 0.2 (Δμ < 0.01): full diversity; μ > 13.8: full parallelism
        let n = 100;
        let d = 0.05;
        assert_eq!(sexp_mean_regime(n, d, 0.1), Regime::FullDiversity);
        assert_eq!(sexp_mean_regime(n, d, 1.0), Regime::Middle);
        assert_eq!(sexp_mean_regime(n, d, 5.0), Regime::Middle);
        assert_eq!(sexp_mean_regime(n, d, 15.0), Regime::FullParallelism);
    }

    #[test]
    fn theorem6_agrees_with_exhaustive_search() {
        let n = 100;
        let d = 0.05;
        for mu in [0.1, 0.5, 1.0, 2.0, 5.0, 14.0, 20.0] {
            let tau = ServiceDist::shifted_exp(d, mu);
            let (b_star, _) = optimal_b_mean(n, &tau);
            match sexp_mean_regime(n, d, mu) {
                Regime::FullDiversity => assert_eq!(b_star, 1, "mu={mu}"),
                Regime::FullParallelism => assert_eq!(b_star, n, "mu={mu}"),
                Regime::Middle => {
                    assert!(b_star > 1 && b_star < n, "mu={mu} B*={b_star}")
                }
                Regime::EitherEnd => unreachable!(),
            }
        }
    }

    #[test]
    fn corollary2_tracks_exhaustive_optimum() {
        let n = 100;
        let d = 0.05;
        for mu in [1.0, 2.0, 4.0, 8.0] {
            let tau = ServiceDist::shifted_exp(d, mu);
            let (b_star, m_star) = optimal_b_mean(n, &tau);
            let b_cor = sexp_mean_optimal_b_cor2(n, d, mu);
            // Corollary 2 is an approximation: allow one feasible step and
            // require near-equal objective values.
            let m_cor = closed_form::sexp_mean(n, b_cor, d, mu);
            assert!(
                (m_cor - m_star) / m_star < 0.05,
                "mu={mu}: B*={b_star} (E={m_star:.4}) vs Cor2 B={b_cor} (E={m_cor:.4})"
            );
        }
    }

    #[test]
    fn theorem7_cov_regimes() {
        let n = 100;
        let d = 0.05;
        // Paper Fig. 8 discussion: μ < 0.8 → full diversity optimal,
        // μ > 0.8 → full parallelism. Our regime fn follows Theorem 7 +
        // Corollary 3.
        let small = sexp_cov_regime(n, d, 0.01 / d); // Δμ = 0.01 < 3/((√5−1)100)≈0.0243
        assert_eq!(small, Regime::FullParallelism);
        let large = sexp_cov_regime(n, d, 2.0 / d); // Δμ = 2 — way past hi
        assert_eq!(large, Regime::FullDiversity);
        // middle band resolves via Corollary 3
        let mid_dm = 0.04;
        assert_eq!(sexp_cov_regime(n, d, mid_dm / d), Regime::EitherEnd);
        let end = sexp_cov_optimal_end(n, d, mid_dm / d);
        assert!(matches!(end, Regime::FullDiversity | Regime::FullParallelism));
    }

    #[test]
    fn theorem7_agrees_with_exhaustive_search() {
        let n = 100;
        let d = 0.05;
        for mu in [0.2, 0.4, 3.0, 30.0] {
            let tau = ServiceDist::shifted_exp(d, mu);
            let (b_star, _) = optimal_b_cov(n, &tau);
            let regime = sexp_cov_regime(n, d, mu);
            match regime {
                Regime::FullParallelism => assert_eq!(b_star, n, "mu={mu}"),
                Regime::FullDiversity => assert_eq!(b_star, 1, "mu={mu}"),
                Regime::EitherEnd => {
                    assert!(b_star == 1 || b_star == n, "mu={mu} B*={b_star}");
                    match sexp_cov_optimal_end(n, d, mu) {
                        Regime::FullParallelism => assert_eq!(b_star, n, "mu={mu}"),
                        Regime::FullDiversity => assert_eq!(b_star, 1, "mu={mu}"),
                        _ => unreachable!(),
                    }
                }
                Regime::Middle => unreachable!(),
            }
        }
    }

    #[test]
    fn pareto_alpha_star_near_paper_value() {
        // Paper: N=100, σ=1 → α* ≈ 4.7
        let a = pareto_alpha_star(100);
        assert!((a - 4.7).abs() < 0.5, "alpha*={a}");
    }

    #[test]
    fn theorem9_agrees_with_exhaustive_search() {
        let n = 100;
        let a_star = pareto_alpha_star(n);
        for alpha in [1.5, 2.5, 3.5] {
            let tau = ServiceDist::pareto(1.0, alpha);
            let (b_star, _) = optimal_b_mean(n, &tau);
            if alpha < a_star {
                assert!(b_star > 1 && b_star < n, "alpha={alpha} B*={b_star}");
            }
        }
        for alpha in [6.0, 8.0] {
            let tau = ServiceDist::pareto(1.0, alpha);
            let (b_star, _) = optimal_b_mean(n, &tau);
            assert_eq!(b_star, n, "alpha={alpha} (alpha*={a_star})");
        }
    }

    #[test]
    fn theorem10_pareto_cov_full_diversity() {
        for alpha in [2.5, 3.0, 5.0, 10.0] {
            let (b, _) = optimal_b_cov(100, &ServiceDist::pareto(1.0, alpha));
            assert_eq!(b, 1, "alpha={alpha}");
        }
        assert_eq!(pareto_cov_regime(), Regime::FullDiversity);
    }

    #[test]
    fn mean_vs_cov_tradeoff_exp() {
        // The paper's headline trade-off: for Exp the two optima are at
        // opposite ends of the spectrum.
        let tau = ServiceDist::exp(1.0);
        let (b_mean, _) = optimal_b_mean(100, &tau);
        let (b_cov, _) = optimal_b_cov(100, &tau);
        assert_eq!((b_mean, b_cov), (1, 100));
        // trade-off weights interpolate between them
        let (b_mid, _) = optimal_b_tradeoff(100, &tau, 0.5);
        assert!((1..=100).contains(&b_mid));
        let (b_all_mean, _) = optimal_b_tradeoff(100, &tau, 1.0);
        assert_eq!(b_all_mean, 1);
        let (b_all_cov, _) = optimal_b_tradeoff(100, &tau, 0.0);
        assert_eq!(b_all_cov, 100);
    }
}
