//! Streaming moment estimation (Welford) + order statistics.

/// Online summary of a sample: mean/variance via Welford's algorithm,
/// plus retained samples for exact quantiles (the experiment scale here
/// — ≤ 10⁷ values — fits comfortably in memory).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    keep_samples: bool,
}

impl Summary {
    /// Summary that retains samples (exact quantiles available).
    pub fn new() -> Summary {
        Summary {
            keep_samples: true,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Memory-light summary (moments only; quantiles unavailable).
    pub fn moments_only() -> Summary {
        Summary {
            keep_samples: false,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.keep_samples {
            self.samples.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample (Bessel-corrected) variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation σ/μ — the paper's predictability metric.
    pub fn cov(&self) -> f64 {
        self.std() / self.mean()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        (self.sample_variance() / self.n as f64).sqrt()
    }

    /// 95% confidence half-width for the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }

    /// Exact quantile (requires retained samples). `q ∈ [0, 1]`.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(self.keep_samples, "quantiles need retained samples");
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.sort_by(f64::total_cmp);
        let idx = ((q * (self.samples.len() - 1) as f64).round()) as usize;
        self.samples[idx]
    }
}

/// Streaming mean for per-replication cost (total worker-seconds).
///
/// A plain sequential sum, not Welford: cost only needs a mean, the
/// record order is the replication order (so the float result is
/// schedule-independent), and a single NaN — a replication whose
/// execution path does not track cost — deliberately poisons the whole
/// mean rather than being silently dropped.
#[derive(Clone, Debug, Default)]
pub struct CostAccumulator {
    sum: f64,
    n: u64,
}

impl CostAccumulator {
    pub fn new() -> CostAccumulator {
        CostAccumulator::default()
    }

    pub fn record(&mut self, cost: f64) {
        self.sum += cost;
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean recorded cost; NaN when nothing was recorded or any
    /// recorded cost was NaN.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert!((s.cov() - 0.4).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles() {
        let mut s = Summary::new();
        for i in 0..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert_eq!(s.quantile(0.95), 95.0);
    }

    #[test]
    fn welford_matches_naive_on_random_data() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.uniform() * 100.0).collect();
        let mut s = Summary::moments_only();
        for &x in &xs {
            s.record(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-9);
        assert!((s.variance() - var).abs() < 1e-6);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.variance().is_nan());
    }

    #[test]
    fn ci_shrinks_with_n() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(4);
        let mut small = Summary::moments_only();
        let mut large = Summary::moments_only();
        for i in 0..10_000 {
            let x = rng.normal();
            if i < 100 {
                small.record(x);
            }
            large.record(x);
        }
        assert!(large.ci95() < small.ci95());
    }

    #[test]
    #[should_panic]
    fn moments_only_has_no_quantiles() {
        let mut s = Summary::moments_only();
        s.record(1.0);
        s.quantile(0.5);
    }

    #[test]
    fn cost_accumulator_means_in_record_order() {
        let mut c = CostAccumulator::new();
        assert!(c.mean().is_nan());
        for x in [1.0, 2.0, 6.0] {
            c.record(x);
        }
        assert_eq!(c.count(), 3);
        assert!((c.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cost_accumulator_propagates_nan() {
        let mut c = CostAccumulator::new();
        c.record(1.0);
        c.record(f64::NAN);
        c.record(2.0);
        assert!(c.mean().is_nan());
    }
}
