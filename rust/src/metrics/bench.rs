//! Minimal benchmarking helper for the `harness = false` bench binaries
//! (no criterion offline — DESIGN.md §Substitutions).
//!
//! Measures wall-clock per iteration with warm-up, reports mean ±
//! stddev over repeats, and returns the mean so benches can assert /
//! derive throughput.

use std::time::Instant;

/// Result of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    /// Mean seconds per iteration.
    pub secs_per_iter: f64,
    /// Std-dev across repeat blocks.
    pub stddev: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_second(&self) -> f64 {
        1.0 / self.secs_per_iter
    }
}

/// Time `f`, auto-calibrating the iteration count to ~`target_ms` per
/// block, running 5 blocks. Prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // warm-up + calibration
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt * 1e3 >= target_ms.min(50.0) || iters >= 1 << 30 {
            let scale = (target_ms / 1e3 / (dt / iters as f64)).max(1.0);
            iters = (scale as u64).clamp(1, 1 << 30);
            break;
        }
        iters *= 4;
    }
    const BLOCKS: usize = 5;
    let mut per_iter = Vec::with_capacity(BLOCKS);
    for _ in 0..BLOCKS {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let mean = per_iter.iter().sum::<f64>() / BLOCKS as f64;
    let var =
        per_iter.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / BLOCKS as f64;
    let result = BenchResult { secs_per_iter: mean, stddev: var.sqrt(), iters };
    println!(
        "bench {name:<48} {:>12}/iter  ± {:>10}  ({} iters/block)",
        humanize(mean),
        humanize(result.stddev),
        iters
    );
    result
}

fn humanize(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let r = bench("noop-ish", 5.0, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.secs_per_iter > 0.0 && r.secs_per_iter < 0.01);
        assert!(r.iters >= 1);
    }

    #[test]
    fn humanize_units() {
        assert!(humanize(2.0).ends_with('s'));
        assert!(humanize(2e-3).ends_with("ms"));
        assert!(humanize(2e-6).ends_with("us"));
        assert!(humanize(2e-9).ends_with("ns"));
    }
}
