//! CSV / JSON export of experiment series (so figures can be re-plotted
//! outside the harness).

use std::path::Path;

use crate::util::csv::Table as CsvTable;
use crate::util::error::Result;
use crate::util::json::Json;

/// A named (x, y...) series, e.g. one curve of a paper figure.
#[derive(Clone, Debug)]
pub struct SeriesExport {
    pub name: String,
    pub x_label: String,
    pub y_labels: Vec<String>,
    /// rows of (x, ys...)
    pub points: Vec<(f64, Vec<f64>)>,
}

impl SeriesExport {
    pub fn new(name: &str, x_label: &str, y_labels: Vec<&str>) -> SeriesExport {
        SeriesExport {
            name: name.to_string(),
            x_label: x_label.to_string(),
            y_labels: y_labels.into_iter().map(String::from).collect(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, ys: Vec<f64>) {
        debug_assert_eq!(ys.len(), self.y_labels.len());
        self.points.push((x, ys));
    }
}

/// Write one or more series as a long-format CSV
/// (`series,x,<y_labels...>`).
pub fn export_csv(path: &Path, series: &[SeriesExport]) -> Result<()> {
    let mut header = vec!["series".to_string(), "x".to_string()];
    if let Some(first) = series.first() {
        header.extend(first.y_labels.iter().cloned());
    }
    let mut table = CsvTable { header, rows: Vec::new() };
    for s in series {
        for (x, ys) in &s.points {
            let mut row = vec![s.name.clone(), format!("{x}")];
            row.extend(ys.iter().map(|y| format!("{y}")));
            table.rows.push(row);
        }
    }
    table.write_to(path)
}

/// Write series as a JSON document.
pub fn export_json(path: &Path, series: &[SeriesExport]) -> Result<()> {
    let arr = Json::Arr(
        series
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("x_label", Json::Str(s.x_label.clone())),
                    (
                        "y_labels",
                        Json::Arr(s.y_labels.iter().map(|l| Json::Str(l.clone())).collect()),
                    ),
                    (
                        "points",
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|(x, ys)| {
                                    let mut v = vec![Json::Num(*x)];
                                    v.extend(ys.iter().map(|y| Json::Num(*y)));
                                    Json::Arr(v)
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    std::fs::write(path, arr.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_json_roundtrip() {
        let dir = std::env::temp_dir().join("replica_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = SeriesExport::new("fig7_mu1", "B", vec!["mean", "ci95"]);
        s.push(1.0, vec![5.2, 0.01]);
        s.push(2.0, vec![3.1, 0.02]);

        let csv_path = dir.join("s.csv");
        export_csv(&csv_path, &[s.clone()]).unwrap();
        let t = CsvTable::read_from(&csv_path).unwrap();
        assert_eq!(t.header, vec!["series", "x", "mean", "ci95"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "fig7_mu1");

        let json_path = dir.join("s.json");
        export_json(&json_path, &[s]).unwrap();
        let text = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        let first = &v.as_arr().unwrap()[0];
        assert_eq!(first.get("name").unwrap().as_str().unwrap(), "fig7_mu1");
        std::fs::remove_dir_all(&dir).ok();
    }
}
