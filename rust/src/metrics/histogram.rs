//! Fixed-bin and log-scale histograms (service-time CCDF plots, Fig 11).

/// A histogram over `[lo, hi)` with uniform or log-spaced bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    log_scale: bool,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    total: u64,
}

impl Histogram {
    pub fn uniform(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            log_scale: false,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    /// Log-spaced bins (lo must be > 0) — right scale for heavy tails.
    pub fn logarithmic(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && lo > 0.0 && bins > 0);
        Histogram {
            lo,
            hi,
            log_scale: true,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let frac = if self.log_scale {
            (x.ln() - self.lo.ln()) / (self.hi.ln() - self.lo.ln())
        } else {
            (x - self.lo) / (self.hi - self.lo)
        };
        let idx = ((frac * self.counts.len() as f64) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Bin edges (len = bins + 1).
    pub fn edges(&self) -> Vec<f64> {
        let b = self.counts.len();
        (0..=b)
            .map(|i| {
                let f = i as f64 / b as f64;
                if self.log_scale {
                    (self.lo.ln() + f * (self.hi.ln() - self.lo.ln())).exp()
                } else {
                    self.lo + f * (self.hi - self.lo)
                }
            })
            .collect()
    }

    /// Empirical CCDF evaluated at each bin's lower edge:
    /// `(edge, Pr{X > edge})` pairs — the Fig. 11 series.
    pub fn ccdf_points(&self) -> Vec<(f64, f64)> {
        let edges = self.edges();
        let mut above = self.total - self.underflow; // count ≥ lo
        let mut pts = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            pts.push((edges[i], above as f64 / self.total.max(1) as f64));
            above -= c;
        }
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_binning() {
        let mut h = Histogram::uniform(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        assert_eq!(h.counts(), &[1; 10]);
        h.record(-1.0);
        h.record(100.0);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn log_binning_spans_decades() {
        let mut h = Histogram::logarithmic(1.0, 1000.0, 3);
        h.record(2.0); // decade 1
        h.record(20.0); // decade 2
        h.record(200.0); // decade 3
        assert_eq!(h.counts(), &[1, 1, 1]);
        let e = h.edges();
        assert!((e[1] - 10.0).abs() < 1e-9);
        assert!((e[2] - 100.0).abs() < 1e-9);
    }

    #[test]
    fn ccdf_monotone_decreasing() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(1);
        let mut h = Histogram::uniform(0.0, 5.0, 50);
        for _ in 0..10_000 {
            h.record(-rng.uniform_pos().ln()); // Exp(1)
        }
        let pts = h.ccdf_points();
        assert!((pts[0].1 - 1.0).abs() < 0.01);
        for w in pts.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
        // CCDF at t≈1 should be ≈ e^{-1}
        let near_1 = pts.iter().min_by(|a, b| {
            (a.0 - 1.0).abs().partial_cmp(&(b.0 - 1.0).abs()).unwrap()
        });
        assert!((near_1.unwrap().1 - (-1.0f64).exp()).abs() < 0.03);
    }
}
