//! ASCII table rendering for the bench harness — each paper table and
//! figure is printed as rows the way the paper reports them.

/// A simple column-aligned ASCII table.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: Vec<&str>) -> Table {
        Table {
            title: title.to_string(),
            header: header.into_iter().map(String::from).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with sensible precision for reports.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 1.0 {
        format!("{x:.3}")
    } else {
        format!("{x:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", vec!["B", "E[T]"]);
        t.row(vec!["1".into(), "5.187".into()]);
        t.row(vec!["100".into(), "0.519".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("E[T]"));
        assert_eq!(s.lines().count(), 5);
        // right-aligned: both data rows end at the same column
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.5), "1234");
        assert_eq!(fnum(1.23456), "1.235");
        assert_eq!(fnum(0.012345), "0.01235");
        assert_eq!(fnum(f64::INFINITY), "inf");
    }
}
