//! Measurement substrates: streaming summaries, histograms, ASCII
//! tables for the bench harness, and CSV/JSON export.

pub mod bench;
mod export;
mod histogram;
mod summary;
mod table;

pub use bench::{bench, BenchResult};
pub use export::{export_csv, export_json, SeriesExport};
pub use histogram::Histogram;
pub use summary::{CostAccumulator, Summary};
pub use table::{fnum, Table};
