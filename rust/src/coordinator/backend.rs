//! Compute backends for worker threads.
//!
//! [`PjrtBackend`] is the production path: gradients run inside the
//! AOT-compiled XLA executable (JAX+Pallas lowered at build time).
//! [`NativeBackend`] is a pure-Rust reference used for tests without
//! artifacts and as the numeric cross-check of the PJRT path.

use crate::runtime::GradientOps;
use crate::util::error::Result;

/// A per-shard gradient evaluator usable from any worker thread.
pub trait ComputeBackend: Send + Sync {
    /// Feature dimension d.
    fn d(&self) -> usize;
    /// Shard rows m.
    fn m(&self) -> usize;
    /// Mean gradient and mean loss over one shard
    /// (`g = Xᵀ(Xβ−y)/m`, `loss = ‖Xβ−y‖²/2m`).
    fn partial_grad_loss(&self, beta: &[f32], x: &[f32], y: &[f32])
        -> Result<(Vec<f32>, f32)>;

    /// Keyed variant: `shard_key` identifies immutable shard data so
    /// backends may cache it device-side (§Perf). Defaults to the
    /// uncached path.
    fn partial_grad_loss_keyed(
        &self,
        _shard_key: u64,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.partial_grad_loss(beta, x, y)
    }
}

/// Pure-Rust reference backend.
#[derive(Clone, Debug)]
pub struct NativeBackend {
    pub m: usize,
    pub d: usize,
}

impl NativeBackend {
    pub fn new(m: usize, d: usize) -> NativeBackend {
        NativeBackend { m, d }
    }
}

impl ComputeBackend for NativeBackend {
    fn d(&self) -> usize {
        self.d
    }

    fn m(&self) -> usize {
        self.m
    }

    fn partial_grad_loss(
        &self,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        let (m, d) = (self.m, self.d);
        debug_assert_eq!(beta.len(), d);
        debug_assert_eq!(x.len(), m * d);
        debug_assert_eq!(y.len(), m);
        let mut grad = vec![0.0f32; d];
        let mut loss = 0.0f32;
        for r in 0..m {
            let row = &x[r * d..(r + 1) * d];
            let mut pred = 0.0f32;
            for j in 0..d {
                pred += row[j] * beta[j];
            }
            let resid = pred - y[r];
            loss += 0.5 * resid * resid;
            for j in 0..d {
                grad[j] += row[j] * resid;
            }
        }
        let inv_m = 1.0 / m as f32;
        for g in grad.iter_mut() {
            *g *= inv_m;
        }
        Ok((grad, loss * inv_m))
    }
}

/// PJRT backend: delegates to the AOT artifact via the runtime thread.
/// (`RuntimeHandle` is `Send + Sync`: an `mpsc::Sender` plus immutable
/// manifest data.)
#[derive(Clone)]
pub struct PjrtBackend {
    ops: GradientOps,
}

impl PjrtBackend {
    pub fn new(ops: GradientOps) -> PjrtBackend {
        PjrtBackend { ops }
    }

    pub fn ops(&self) -> &GradientOps {
        &self.ops
    }
}

impl ComputeBackend for PjrtBackend {
    fn d(&self) -> usize {
        self.ops.d
    }

    fn m(&self) -> usize {
        self.ops.m
    }

    fn partial_grad_loss(
        &self,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.ops.partial_grad_loss(beta, x, y)
    }

    fn partial_grad_loss_keyed(
        &self,
        shard_key: u64,
        beta: &[f32],
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        self.ops.partial_grad_loss_cached(beta, shard_key, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::data::Dataset;

    #[test]
    fn native_backend_matches_analytic_gradient() {
        // one shard, y = Xβ* exactly, evaluate at β = β* → zero grad/loss
        let ds = Dataset::synthetic(1, 32, 6, 0.0, 3);
        let nb = NativeBackend::new(32, 6);
        let (g, loss) =
            nb.partial_grad_loss(&ds.beta_star, &ds.shards[0].x, &ds.shards[0].y).unwrap();
        assert!(loss < 1e-10, "loss {loss}");
        assert!(g.iter().all(|v| v.abs() < 1e-4), "{g:?}");
    }

    #[test]
    fn native_backend_zero_beta() {
        // β = 0: g = −Xᵀy/m, loss = ‖y‖²/2m
        let ds = Dataset::synthetic(1, 16, 4, 0.2, 5);
        let nb = NativeBackend::new(16, 4);
        let zero = vec![0.0f32; 4];
        let s = &ds.shards[0];
        let (g, loss) = nb.partial_grad_loss(&zero, &s.x, &s.y).unwrap();
        let want_loss: f32 = s.y.iter().map(|v| 0.5 * v * v).sum::<f32>() / 16.0;
        assert!((loss - want_loss).abs() < 1e-5);
        let mut want_g = vec![0.0f32; 4];
        for r in 0..16 {
            for j in 0..4 {
                want_g[j] -= s.x[r * 4 + j] * s.y[r] / 16.0;
            }
        }
        for (a, b) in g.iter().zip(&want_g) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn backend_is_shareable_across_threads() {
        use std::sync::Arc;
        let ds = Arc::new(Dataset::synthetic(2, 8, 3, 0.1, 9));
        let nb: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(8, 3));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let nb = nb.clone();
                let ds = ds.clone();
                std::thread::spawn(move || {
                    let beta = vec![0.1f32; 3];
                    nb.partial_grad_loss(&beta, &ds.shards[0].x, &ds.shards[0].y).unwrap()
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results[1..] {
            assert_eq!(r.0, results[0].0);
        }
    }
}
