//! The master: replication-aware round loop with first-copy-wins
//! aggregation.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batching::{Layout, Policy};
use crate::coordinator::backend::ComputeBackend;
use crate::coordinator::data::Dataset;
use crate::coordinator::worker::{worker_loop, WorkItem, WorkResult};
use crate::dist::ServiceDist;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Configuration of a distributed-GD run.
#[derive(Clone, Debug)]
pub struct GdConfig {
    /// Worker budget N (= number of tasks/shards).
    pub workers: usize,
    /// Batch count B (B | N). Use the planner to choose.
    pub batches: usize,
    /// GD rounds to run.
    pub rounds: usize,
    /// Learning rate.
    pub lr: f32,
    /// Straggler model: per-task service time τ; a worker's delay is
    /// `|batch| · τ` (the size-dependent model).
    pub straggler: ServiceDist,
    /// Wall-clock seconds per service-time unit (scale delays down so
    /// experiments run fast; latency *ratios* are preserved).
    pub time_scale: f64,
    /// RNG seed (straggler delays).
    pub seed: u64,
}

impl GdConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.batches == 0 || self.workers % self.batches != 0 {
            return Err(Error::Config(format!(
                "batches B={} must divide workers N={}",
                self.batches, self.workers
            )));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be >= 1".into()));
        }
        if !self.time_scale.is_finite() || self.time_scale < 0.0 {
            return Err(Error::Config("time_scale must be finite and >= 0".into()));
        }
        Ok(())
    }
}

/// Per-round statistics.
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    /// Wall-clock round latency (seconds).
    pub latency: f64,
    /// Mean training loss reported this round.
    pub loss: f64,
    /// Replica results that arrived after their batch was already
    /// covered (wasted work — the cost of redundancy).
    pub discarded: usize,
}

/// Result of a full training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub rounds: Vec<RoundStats>,
    pub final_beta: Vec<f32>,
    /// Global dataset loss of the final model.
    pub final_global_loss: f64,
    /// Total results discarded by first-copy-wins.
    pub total_discarded: usize,
}

impl TrainReport {
    pub fn losses(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.loss).collect()
    }

    pub fn mean_latency(&self) -> f64 {
        self.rounds.iter().map(|r| r.latency).sum::<f64>() / self.rounds.len().max(1) as f64
    }
}

/// The master node: owns the worker pool and the round loop.
pub struct Coordinator {
    cfg: GdConfig,
    dataset: Arc<Dataset>,
    layout: Layout,
    work_txs: Vec<Sender<WorkItem>>,
    result_rx: Receiver<WorkResult>,
    joins: Vec<JoinHandle<()>>,
    rng: Pcg64,
    beta: Vec<f32>,
}

impl Coordinator {
    /// Spawn the worker pool. `dataset.n_shards()` must equal
    /// `cfg.workers` (task t = shard t).
    pub fn new(
        cfg: GdConfig,
        dataset: Dataset,
        backend: Arc<dyn ComputeBackend>,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        if dataset.n_shards() != cfg.workers {
            return Err(Error::Config(format!(
                "dataset has {} shards but config wants N={} workers",
                dataset.n_shards(),
                cfg.workers
            )));
        }
        if dataset.m_per_shard != backend.m() || dataset.d != backend.d() {
            return Err(Error::Config(format!(
                "dataset shape ({}, {}) does not match backend ({}, {})",
                dataset.m_per_shard,
                dataset.d,
                backend.m(),
                backend.d()
            )));
        }
        let mut rng = Pcg64::new(cfg.seed);
        let layout = Policy::BalancedNonOverlapping { batches: cfg.batches }
            .layout(cfg.workers, &mut rng)?;
        let dataset = Arc::new(dataset);
        let (result_tx, result_rx) = channel::<WorkResult>();
        let mut work_txs = Vec::with_capacity(cfg.workers);
        let mut joins = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = channel::<WorkItem>();
            work_txs.push(tx);
            let backend = backend.clone();
            let dataset = dataset.clone();
            let result_tx = result_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("replica-worker-{w}"))
                .spawn(move || worker_loop(w, backend, dataset, rx, result_tx))
                .map_err(|e| Error::Coordinator(format!("spawn worker {w}: {e}")))?;
            joins.push(join);
        }
        let d = dataset.d;
        Ok(Coordinator {
            cfg,
            dataset,
            layout,
            work_txs,
            result_rx,
            joins,
            rng,
            beta: vec![0.0f32; d],
        })
    }

    /// The materialized replication layout.
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Current model.
    pub fn beta(&self) -> &[f32] {
        &self.beta
    }

    /// Run the configured number of rounds.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut total_discarded = 0usize;
        let mut received = 0usize;
        for round in 0..self.cfg.rounds {
            let stats = self.run_round(round, &mut received)?;
            total_discarded += stats.discarded;
            rounds.push(stats);
        }
        // Drain the stragglers of the final round(s) so the discard
        // accounting is exact and worker channels end empty. Every worker
        // reports exactly once per round.
        let expected = self.cfg.workers * self.cfg.rounds;
        while received < expected {
            let res = self
                .result_rx
                .recv()
                .map_err(|_| Error::Coordinator("all workers hung up".into()))?;
            received += 1;
            total_discarded += 1;
            if let Some(msg) = res.error {
                return Err(Error::Coordinator(msg));
            }
        }
        Ok(TrainReport {
            final_global_loss: self.dataset.global_loss(&self.beta),
            final_beta: self.beta.clone(),
            rounds,
            total_discarded,
        })
    }

    fn run_round(&mut self, round: usize, received: &mut usize) -> Result<RoundStats> {
        let b = self.cfg.batches;
        let beta = Arc::new(self.beta.clone());
        let start = Instant::now();

        // Dispatch work to every worker with a sampled straggler delay.
        for w in 0..self.cfg.workers {
            let tasks = Arc::new(self.layout.worker_tasks[w].clone());
            let service = tasks.len() as f64 * self.cfg.straggler.sample(&mut self.rng);
            let delay = Duration::from_secs_f64(service * self.cfg.time_scale);
            // find the batch this worker hosts
            let batch = self
                .layout
                .batch_workers
                .iter()
                .position(|ws| ws.contains(&w))
                .ok_or_else(|| {
                    Error::Internal(format!("worker {w} hosts no batch in the layout"))
                })?;
            self.work_txs[w]
                .send(WorkItem { round, batch, beta: beta.clone(), tasks, delay })
                .map_err(|_| Error::Coordinator(format!("worker {w} hung up")))?;
        }

        // First-copy-wins collection.
        let mut batch_done = vec![false; b];
        let mut done = 0usize;
        let mut grad_sum = vec![0.0f32; self.dataset.d];
        let mut loss_sum = 0.0f64;
        let mut discarded = 0usize;
        while done < b {
            let res = self
                .result_rx
                .recv()
                .map_err(|_| Error::Coordinator("all workers hung up".into()))?;
            *received += 1;
            if let Some(msg) = res.error {
                return Err(Error::Coordinator(msg));
            }
            if res.round != round || batch_done[res.batch] {
                discarded += 1; // late replica (previous round or already covered)
                continue;
            }
            batch_done[res.batch] = true;
            done += 1;
            for (a, g) in grad_sum.iter_mut().zip(&res.grad) {
                *a += g;
            }
            loss_sum += res.loss as f64;
        }

        // Gradient step: mean over batches (batches partition the tasks).
        let inv_b = 1.0 / b as f32;
        for (beta_j, g_j) in self.beta.iter_mut().zip(&grad_sum) {
            *beta_j -= self.cfg.lr * g_j * inv_b;
        }
        Ok(RoundStats {
            latency: start.elapsed().as_secs_f64(),
            loss: loss_sum / b as f64,
            discarded,
        })
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.work_txs.clear(); // close channels; workers exit
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;

    fn quick_cfg(workers: usize, batches: usize, rounds: usize) -> GdConfig {
        GdConfig {
            workers,
            batches,
            rounds,
            lr: 0.1,
            straggler: ServiceDist::shifted_exp(0.01, 10.0),
            time_scale: 1e-4, // keep tests fast
            seed: 7,
        }
    }

    fn run(cfg: GdConfig, m: usize, d: usize, noise: f64, seed: u64) -> TrainReport {
        let ds = Dataset::synthetic(cfg.workers, m, d, noise, seed);
        let backend = Arc::new(NativeBackend::new(m, d));
        let mut c = Coordinator::new(cfg, ds, backend).unwrap();
        c.run().unwrap()
    }

    #[test]
    fn gd_converges_with_replication() {
        let report = run(quick_cfg(8, 2, 120), 16, 4, 0.0, 11);
        let losses = report.losses();
        assert!(losses[0] > 10.0 * losses[losses.len() - 1].max(1e-12));
        assert!(report.final_global_loss < 1e-3, "{}", report.final_global_loss);
    }

    #[test]
    fn replication_discards_late_copies() {
        // B=2 on 8 workers → 4 replicas per batch → 3 discarded per batch
        let report = run(quick_cfg(8, 2, 10), 8, 3, 0.1, 12);
        // per round: 8 results, 2 winners → 6 discarded
        assert_eq!(report.total_discarded, 10 * 6);
    }

    #[test]
    fn full_parallelism_discards_nothing() {
        let report = run(quick_cfg(4, 4, 8), 8, 3, 0.1, 13);
        assert_eq!(report.total_discarded, 0);
    }

    #[test]
    fn different_b_same_convergence_target() {
        // replication changes latency, NOT the gradient math: all B
        // values must converge to (near-)identical losses
        let l2 = run(quick_cfg(8, 2, 80), 16, 4, 0.05, 14).final_global_loss;
        let l8 = run(quick_cfg(8, 8, 80), 16, 4, 0.05, 14).final_global_loss;
        assert!((l2 - l8).abs() / l8 < 0.05, "{l2} vs {l8}");
    }

    #[test]
    fn config_validation() {
        assert!(quick_cfg(8, 3, 1).validate().is_err());
        assert!(quick_cfg(0, 1, 1).validate().is_err());
        let mut c = quick_cfg(8, 2, 0);
        assert!(c.validate().is_err());
        c.rounds = 1;
        c.validate().unwrap();
    }

    #[test]
    fn mismatched_dataset_rejected() {
        let cfg = quick_cfg(8, 2, 1);
        let ds = Dataset::synthetic(4, 16, 4, 0.0, 1); // wrong shard count
        assert!(Coordinator::new(cfg, ds, Arc::new(NativeBackend::new(16, 4))).is_err());
        let cfg = quick_cfg(8, 2, 1);
        let ds = Dataset::synthetic(8, 16, 4, 0.0, 1);
        // wrong backend shape
        assert!(Coordinator::new(cfg, ds, Arc::new(NativeBackend::new(8, 4))).is_err());
    }

    /// A backend that fails on one chosen task — by returning `Err` or
    /// by panicking — and behaves natively everywhere else. Exercises
    /// the liveness contract: the master must surface a proper error,
    /// never hang waiting for a result that will not come.
    struct FaultyBackend {
        inner: NativeBackend,
        bad_task: u64,
        panics: bool,
    }

    impl ComputeBackend for FaultyBackend {
        fn d(&self) -> usize {
            self.inner.d()
        }
        fn m(&self) -> usize {
            self.inner.m()
        }
        fn partial_grad_loss(
            &self,
            beta: &[f32],
            x: &[f32],
            y: &[f32],
        ) -> Result<(Vec<f32>, f32)> {
            self.inner.partial_grad_loss(beta, x, y)
        }
        fn partial_grad_loss_keyed(
            &self,
            shard_key: u64,
            beta: &[f32],
            x: &[f32],
            y: &[f32],
        ) -> Result<(Vec<f32>, f32)> {
            if shard_key == self.bad_task {
                if self.panics {
                    panic!("injected backend panic");
                }
                return Err(Error::Runtime("injected backend failure".into()));
            }
            self.inner.partial_grad_loss_keyed(shard_key, beta, x, y)
        }
    }

    fn run_faulty(panics: bool) -> Error {
        // B = N: every batch has exactly one host, so losing the faulty
        // worker's result can never be papered over by a replica — the
        // pre-fix behavior was a hung `recv()`, not an error
        let cfg = quick_cfg(4, 4, 3);
        let ds = Dataset::synthetic(4, 8, 3, 0.1, 5);
        let backend =
            Arc::new(FaultyBackend { inner: NativeBackend::new(8, 3), bad_task: 2, panics });
        let mut c = Coordinator::new(cfg, ds, backend).unwrap();
        c.run().unwrap_err()
    }

    #[test]
    fn backend_error_fails_the_round_instead_of_hanging() {
        let err = run_faulty(false);
        assert!(err.to_string().contains("injected backend failure"), "{err}");
    }

    #[test]
    fn backend_panic_fails_the_round_instead_of_hanging() {
        let err = run_faulty(true);
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("injected backend panic"), "{err}");
    }

    #[test]
    fn diversity_reduces_latency_under_stragglers() {
        // Heavy-tailed stragglers + measurable delays: B=1 (full
        // diversity) should beat B=N (no redundancy) on round latency.
        // time_scale large enough that sampled delays (~10–100 ms)
        // dominate thread-scheduling noise (~1 ms).
        let straggler = ServiceDist::pareto(0.05, 1.1);
        let base = GdConfig {
            workers: 8,
            batches: 1,
            rounds: 12,
            lr: 0.05,
            straggler: straggler.clone(),
            time_scale: 2e-2,
            seed: 21,
        };
        let lat_div = {
            let ds = Dataset::synthetic(8, 8, 3, 0.1, 2);
            let mut c =
                Coordinator::new(base.clone(), ds, Arc::new(NativeBackend::new(8, 3))).unwrap();
            c.run().unwrap().mean_latency()
        };
        let lat_par = {
            let mut cfg = base;
            cfg.batches = 8;
            let ds = Dataset::synthetic(8, 8, 3, 0.1, 2);
            let mut c = Coordinator::new(cfg, ds, Arc::new(NativeBackend::new(8, 3))).unwrap();
            c.run().unwrap().mean_latency()
        };
        assert!(
            lat_div < lat_par,
            "full diversity {lat_div:.4}s should beat full parallelism {lat_par:.4}s"
        );
    }
}
