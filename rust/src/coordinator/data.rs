//! Synthetic linear-regression datasets, sharded task-wise.
//!
//! Task `t` of the paper's N-parallelizable job = shard `t` here: the
//! gradient over shard `t` is the unit of work that gets replicated.

use crate::util::rng::Pcg64;

/// One task's data shard (row-major `x`, length `m·d`; targets length
/// `m`).
#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

/// A sharded regression dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub d: usize,
    pub m_per_shard: usize,
    pub shards: Vec<Shard>,
    /// Ground-truth coefficients (for convergence checks).
    pub beta_star: Vec<f32>,
}

impl Dataset {
    /// Generate `n_shards` shards of `m` rows each: `y = X·β* + ε`,
    /// `X ~ N(0,1)`, `ε ~ N(0, noise²)`.
    pub fn synthetic(n_shards: usize, m: usize, d: usize, noise: f64, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed);
        let beta_star: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let shards = (0..n_shards)
            .map(|_| {
                let mut x = Vec::with_capacity(m * d);
                let mut y = Vec::with_capacity(m);
                for _ in 0..m {
                    let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    let mut dot = 0.0f32;
                    for (a, b) in row.iter().zip(&beta_star) {
                        dot += a * b;
                    }
                    y.push(dot + (noise * rng.normal()) as f32);
                    x.extend(row);
                }
                Shard { x, y }
            })
            .collect();
        Dataset { d, m_per_shard: m, shards, beta_star }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global mean loss of a model over all shards (reference metric).
    pub fn global_loss(&self, beta: &[f32]) -> f64 {
        let mut total = 0.0f64;
        let mut rows = 0usize;
        for s in &self.shards {
            for r in 0..self.m_per_shard {
                let mut pred = 0.0f32;
                for j in 0..self.d {
                    pred += s.x[r * self.d + j] * beta[j];
                }
                let e = (pred - s.y[r]) as f64;
                total += 0.5 * e * e;
                rows += 1;
            }
        }
        total / rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = Dataset::synthetic(4, 16, 8, 0.1, 42);
        let b = Dataset::synthetic(4, 16, 8, 0.1, 42);
        assert_eq!(a.n_shards(), 4);
        assert_eq!(a.shards[0].x.len(), 16 * 8);
        assert_eq!(a.shards[0].y.len(), 16);
        assert_eq!(a.beta_star, b.beta_star);
        assert_eq!(a.shards[2].x, b.shards[2].x);
        let c = Dataset::synthetic(4, 16, 8, 0.1, 43);
        assert_ne!(a.shards[0].x, c.shards[0].x);
    }

    #[test]
    fn ground_truth_has_noise_level_loss() {
        let noiseless = Dataset::synthetic(4, 64, 6, 0.0, 7);
        assert!(noiseless.global_loss(&noiseless.beta_star) < 1e-10);
        let noisy = Dataset::synthetic(4, 256, 6, 0.5, 7);
        let l = noisy.global_loss(&noisy.beta_star);
        // E[0.5 ε²] = 0.5·0.25 = 0.125
        assert!((l - 0.125).abs() < 0.03, "loss {l}");
    }

    #[test]
    fn zero_model_has_large_loss() {
        let ds = Dataset::synthetic(2, 64, 8, 0.0, 1);
        let zero = vec![0.0f32; 8];
        assert!(ds.global_loss(&zero) > ds.global_loss(&ds.beta_star) + 0.5);
    }
}
