//! Worker threads: sleep out the straggler delay, compute the batch
//! gradient, report to the master.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::backend::ComputeBackend;
use crate::coordinator::data::Dataset;

/// Work sent from master to one worker for one round.
pub(crate) struct WorkItem {
    pub round: usize,
    pub batch: usize,
    /// Model snapshot.
    pub beta: Arc<Vec<f32>>,
    /// Task (shard) ids in this worker's batch.
    pub tasks: Arc<Vec<usize>>,
    /// Straggler delay (already scaled to wall-clock seconds).
    pub delay: Duration,
}

/// Result sent from a worker to the master.
pub(crate) struct WorkResult {
    pub round: usize,
    /// Reporting worker id (kept for logging/metrics hooks).
    #[allow(dead_code)]
    pub worker: usize,
    pub batch: usize,
    /// Mean gradient over the batch's tasks.
    pub grad: Vec<f32>,
    /// Mean loss over the batch's tasks.
    pub loss: f32,
    /// Worker-side error message, if any.
    pub error: Option<String>,
}

/// The worker thread body: loop over rounds until the channel closes.
pub(crate) fn worker_loop(
    id: usize,
    backend: Arc<dyn ComputeBackend>,
    dataset: Arc<Dataset>,
    rx: Receiver<WorkItem>,
    tx: Sender<WorkResult>,
) {
    while let Ok(item) = rx.recv() {
        // Straggler injection: the sampled service delay.
        if item.delay > Duration::ZERO {
            std::thread::sleep(item.delay);
        }
        let d = backend.d();
        let mut grad_sum = vec![0.0f32; d];
        let mut loss_sum = 0.0f32;
        let mut error = None;
        for &t in item.tasks.iter() {
            let shard = &dataset.shards[t];
            match backend.partial_grad_loss_keyed(t as u64, &item.beta, &shard.x, &shard.y) {
                Ok((g, l)) => {
                    for (a, b) in grad_sum.iter_mut().zip(&g) {
                        *a += b;
                    }
                    loss_sum += l;
                }
                Err(e) => {
                    error = Some(format!("worker {id} task {t}: {e}"));
                    break;
                }
            }
        }
        let k = item.tasks.len().max(1) as f32;
        for g in grad_sum.iter_mut() {
            *g /= k;
        }
        let send_result = tx.send(WorkResult {
            round: item.round,
            worker: id,
            batch: item.batch,
            grad: grad_sum,
            loss: loss_sum / k,
            error,
        });
        if send_result.is_err() {
            break; // master is gone
        }
    }
}
