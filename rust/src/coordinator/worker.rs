//! Worker threads: sleep out the straggler delay, compute the batch
//! gradient, report to the master.
//!
//! **Liveness contract:** every [`WorkItem`] produces exactly one
//! [`WorkResult`], even when the backend errors *or panics*. The
//! master's first-copy-wins collector counts results, so a worker
//! that swallowed an item would hang the round forever — a panicking
//! backend is therefore caught ([`std::panic::catch_unwind`]) and
//! reported as an error result instead of silently killing the thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::backend::ComputeBackend;
use crate::coordinator::data::Dataset;

/// Work sent from master to one worker for one round.
pub(crate) struct WorkItem {
    pub round: usize,
    pub batch: usize,
    /// Model snapshot.
    pub beta: Arc<Vec<f32>>,
    /// Task (shard) ids in this worker's batch.
    pub tasks: Arc<Vec<usize>>,
    /// Straggler delay (already scaled to wall-clock seconds).
    pub delay: Duration,
}

/// Result sent from a worker to the master.
pub(crate) struct WorkResult {
    pub round: usize,
    /// Reporting worker id (kept for logging/metrics hooks).
    #[allow(dead_code)]
    pub worker: usize,
    pub batch: usize,
    /// Mean gradient over the batch's tasks.
    pub grad: Vec<f32>,
    /// Mean loss over the batch's tasks.
    pub loss: f32,
    /// Worker-side error message, if any.
    pub error: Option<String>,
}

/// Best-effort text of a panic payload (`panic!("...")` carries a
/// `&str` or a `String`; anything else stays opaque).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Compute one item's mean gradient and loss; an `Err` from the backend
/// becomes the result's error field.
fn compute(
    id: usize,
    d: usize,
    backend: &dyn ComputeBackend,
    dataset: &Dataset,
    item: &WorkItem,
) -> (Vec<f32>, f32, Option<String>) {
    let mut grad_sum = vec![0.0f32; d];
    let mut loss_sum = 0.0f32;
    let mut error = None;
    for &t in item.tasks.iter() {
        let shard = &dataset.shards[t];
        match backend.partial_grad_loss_keyed(t as u64, &item.beta, &shard.x, &shard.y) {
            Ok((g, l)) => {
                for (a, b) in grad_sum.iter_mut().zip(&g) {
                    *a += b;
                }
                loss_sum += l;
            }
            Err(e) => {
                error = Some(format!("worker {id} task {t}: {e}"));
                break;
            }
        }
    }
    let k = item.tasks.len().max(1) as f32;
    for g in grad_sum.iter_mut() {
        *g /= k;
    }
    (grad_sum, loss_sum / k, error)
}

/// The worker thread body: loop over rounds until the channel closes.
pub(crate) fn worker_loop(
    id: usize,
    backend: Arc<dyn ComputeBackend>,
    dataset: Arc<Dataset>,
    rx: Receiver<WorkItem>,
    tx: Sender<WorkResult>,
) {
    // the model width is fixed for the run (validated against the
    // dataset in `Coordinator::new`)
    let d = backend.d();
    while let Ok(item) = rx.recv() {
        // Straggler injection: the sampled service delay.
        if item.delay > Duration::ZERO {
            std::thread::sleep(item.delay);
        }
        // a panicking backend must still yield a result, or the master
        // waits forever on a round this worker will never report
        let outcome =
            catch_unwind(AssertUnwindSafe(|| compute(id, d, &*backend, &dataset, &item)));
        let (grad, loss, error) = match outcome {
            Ok(result) => result,
            Err(payload) => (
                vec![0.0f32; d],
                0.0,
                Some(format!(
                    "worker {id} batch {} panicked: {}",
                    item.batch,
                    panic_text(&*payload)
                )),
            ),
        };
        let send_result = tx.send(WorkResult {
            round: item.round,
            worker: id,
            batch: item.batch,
            grad,
            loss,
            error,
        });
        if send_result.is_err() {
            break; // master is gone
        }
    }
}
