//! The live master–worker coordinator (L3).
//!
//! This is the deployable version of the paper's system (Fig. 1): a
//! master thread drives synchronous distributed gradient descent over a
//! pool of worker threads. Each round:
//!
//! 1. the master broadcasts the model `beta` and a replication layout
//!    produced by the [`planner`](crate::planner);
//! 2. every worker waits out a sampled straggler delay (the service-time
//!    model under test), then computes its batch's gradient — through
//!    the PJRT runtime ([`PjrtBackend`]) or the pure-Rust reference
//!    backend ([`NativeBackend`]);
//! 3. the master applies **first-copy-wins** per batch (eq. 8), ignores
//!    late replicas, and steps the model once all batches are covered
//!    (eq. 9).
//!
//! Worker threads are real OS threads with real (scaled) delays, so
//! round latency genuinely follows `max_batch min_replica` — the
//! quantity the paper analyzes.

mod backend;
mod data;
mod master;
mod worker;

pub use backend::{ComputeBackend, NativeBackend, PjrtBackend};
pub use data::{Dataset, Shard};
pub use master::{Coordinator, GdConfig, RoundStats, TrainReport};
