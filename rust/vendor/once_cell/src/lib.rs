//! Minimal offline stand-in for `once_cell` (the `sync::OnceCell` slice
//! this repo uses), backed by `std::sync::OnceLock`.

pub mod sync {
    /// A thread-safe cell that can be written to at most once.
    #[derive(Debug)]
    pub struct OnceCell<T> {
        inner: std::sync::OnceLock<T>,
    }

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell { inner: std::sync::OnceLock::new() }
        }

        pub fn get(&self) -> Option<&T> {
            self.inner.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.inner.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.inner.get_or_init(f)
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> OnceCell<T> {
            OnceCell::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::OnceCell;

    #[test]
    fn init_once() {
        static CELL: OnceCell<u32> = OnceCell::new();
        assert!(CELL.get().is_none());
        assert_eq!(*CELL.get_or_init(|| 7), 7);
        assert_eq!(*CELL.get_or_init(|| 9), 7);
        assert!(CELL.set(11).is_err());
        assert_eq!(CELL.get(), Some(&7));
    }
}
