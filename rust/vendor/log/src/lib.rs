//! Minimal offline stand-in for the `log` facade crate.
//!
//! The build environment is fully offline (DESIGN.md §Substitutions), so
//! the repo vendors the small slice of `log`'s API it actually uses: the
//! five level macros, the [`Log`] trait, and the global logger /
//! max-level registry. Semantics match the real facade for that slice:
//! records are filtered first by the global max level, then by the
//! installed logger's own [`Log::enabled`].

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging verbosity, ordered `Error < Warn < Info < Debug < Trace`.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Global level filter for [`set_max_level`]; `Off` disables everything.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log record.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        false
    }

    fn log(&self, _record: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (the first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (a no-op logger until [`set_logger`] succeeds).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Set the global maximum level; records above it are skipped early.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level, as its raw ordinal (macro support).
pub fn max_level_raw() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro support: dispatch one record to the installed logger.
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    let record = Record { metadata: Metadata { level, target }, args };
    let logger = logger();
    if logger.enabled(record.metadata()) {
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if (lvl as usize) <= $crate::max_level_raw() {
            $crate::__private_api_log(::core::format_args!($($arg)+), lvl, $target);
        }
    }};
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log!(target: ::core::module_path!(), $lvl, $($arg)+)
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= Level::Info
        }

        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::Relaxed);
            }
        }

        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter;

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Trace);
        let before = HITS.load(Ordering::Relaxed);
        info!("count {}", 1);
        debug!("not counted (logger disabled at Debug)");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 1);
        // a second logger install fails but does not panic
        assert!(set_logger(&COUNTER).is_err());
    }

    #[test]
    fn level_ordering_and_display() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::Warn.as_str(), "WARN");
        assert_eq!(format!("{:5}", Level::Info), "INFO ");
    }
}
