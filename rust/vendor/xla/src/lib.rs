//! Offline stub of the PJRT/XLA Rust bindings.
//!
//! The real engine links the PJRT C API and compiles HLO-text artifacts;
//! this build environment cannot (fully offline, no PJRT shared
//! library — DESIGN.md §Substitutions). The stub mirrors the exact API
//! surface `replica::runtime::engine` consumes and fails cleanly at
//! [`PjRtClient::cpu`], so everything downstream of a client is
//! unreachable at runtime while still typechecking. The PJRT integration
//! tests gate on `artifacts_available()` and skip, and the coordinator
//! falls back to its native Rust backend.

use std::fmt;
use std::path::Path;

/// XLA/PJRT error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: the PJRT/XLA runtime is not linked in this build \
             (offline stub; see DESIGN.md §Substitutions)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct PjRtClient {
    _private: (),
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

pub struct HloModuleProto {
    _private: (),
}

pub struct XlaComputation {
    _private: (),
}

pub struct Literal {
    _private: (),
}

impl PjRtClient {
    /// In the real bindings this brings up the PJRT CPU client; the stub
    /// always errors, which the runtime service surfaces at startup.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_errors_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not construct a client");
        assert!(err.to_string().contains("PJRT"));
    }

    #[test]
    fn hlo_load_errors_cleanly() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
